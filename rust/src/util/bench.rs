//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Every file under `rust/benches/` is a `harness = false` binary that
//! drives this module. A benchmark runs a closure until both a minimum
//! wall-time and a minimum iteration count are met, then reports
//! median / mean / p95 per-iteration time and derived throughput.
//! Results can also be dumped as JSON for EXPERIMENTS.md bookkeeping.

use std::time::{Duration, Instant};

use super::stats::{mean, percentile};

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.median_ns
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

/// Fast options for expensive end-to-end benches.
pub fn slow_opts() -> BenchOpts {
    BenchOpts {
        warmup: Duration::from_millis(50),
        measure: Duration::from_millis(500),
        min_iters: 3,
        max_iters: 10_000,
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run `f` under the harness and return timing stats.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < opts.warmup {
        f();
    }
    // Measure individual iterations.
    let mut samples: Vec<f64> = Vec::with_capacity(1024);
    let start = Instant::now();
    let mut iters = 0u64;
    while (start.elapsed() < opts.measure || iters < opts.min_iters) && iters < opts.max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        iters += 1;
    }
    let median_ns = percentile(&samples, 50.0);
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns,
        mean_ns: mean(&samples),
        p95_ns: percentile(&samples, 95.0),
        min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Run + print one line in a stable, parseable format.
pub fn run<F: FnMut()>(name: &str, opts: BenchOpts, f: F) -> BenchResult {
    let r = bench(name, opts, f);
    println!(
        "bench {:<44} {:>12} ns/iter (mean {:>12}, p95 {:>12}, {:>9.1}/s, n={})",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.mean_ns),
        fmt_ns(r.p95_ns),
        r.per_sec(),
        r.iters
    );
    r
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

// ---------------------------------------------------------------------------
// Plain-text table printer for the experiment benches ("the paper's rows").
// ---------------------------------------------------------------------------

/// Fixed-width table writer: the experiment benches print the same rows
/// the paper's analysis defines, side by side with the measured values.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        println!("\n== {title} ==");
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>width$}", h, width = w[i]))
            .collect();
        println!("{}", hdr.join("  "));
        println!("{}", "-".repeat(hdr.join("  ").len()));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

/// Format helper: 4-significant-digit float cell.
pub fn f(x: f64) -> String {
    format!("{x:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 5,
            max_iters: 100_000,
        };
        let mut acc = 0u64;
        let r = bench("spin", opts, || {
            for i in 0..100u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(r.iters >= 5);
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.p95_ns);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["q", "analytic", "measured"]);
        t.row(&["0.1".into(), f(0.9333), f(0.9329)]);
        t.print("eq2");
        assert_eq!(t.rows.len(), 1);
    }
}
