//! Shared experiment machinery: one-call training runs over the native
//! engine (fast enough for thousands of iterations) with any scheme,
//! attack, and cluster shape.

use std::sync::Arc;

use crate::baselines::GradientFilter;
use crate::config::{
    AdversaryKind, AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, GatherPolicy,
    PolicyKind, TrainConfig, TransportKind,
};
use crate::coordinator::compress::Compressor;
use crate::coordinator::master::{Master, MasterOptions};
use crate::coordinator::{SimConfig, TrainOutcome};
use crate::data::LinRegDataset;
use crate::grad::{GradientComputer, ModelSpec, NativeEngine};
use crate::Result;

/// Declarative description of one run.
#[derive(Clone)]
pub struct RunSpec {
    pub n: usize,
    pub f: usize,
    /// Actually-Byzantine worker ids (defaults to last f workers so the
    /// first chunk owners are honest in trivial configs).
    pub byzantine: Vec<usize>,
    pub policy: PolicyKind,
    pub attack: AttackConfig,
    pub steps: usize,
    pub seed: u64,
    pub d: usize,
    pub chunk: usize,
    pub lr: f32,
    pub self_check: bool,
    /// Label-noise std for the linreg dataset (keeps gradients away
    /// from bit-zero so attacks never degenerate to no-ops).
    pub noise_std: f32,
    /// Measurement mode: identify but never eliminate (holds f_t = f).
    pub no_eliminate: bool,
    /// §2.1/§5: symbol compressor (None = dense).
    pub compressor: Option<Arc<dyn Compressor>>,
    /// §5 hybrid: filter for unaudited aggregation.
    pub unaudited_filter: Option<Arc<dyn GradientFilter>>,
    /// Execution model (threaded by default, matching the pre-transport
    /// experiment harness).
    pub transport: TransportKind,
    /// Shard count K (1 = single master).
    pub shards: usize,
    /// Proactive gather policy.
    pub gather: GatherPolicy,
    /// Coordinated adversary strategy (None = the stateless `attack`).
    pub adversary: Option<AdversaryKind>,
    /// Sim scenario knobs (`transport = Sim` only).
    pub sim: SimConfig,
    /// Round pipeline depth (1 = strictly sequential).
    pub pipeline: usize,
    /// Net transport only: worker addresses in worker-id order.
    pub peers: Vec<String>,
    /// Net transport only: chaos fault-injection spec for the master's
    /// links (see `docs/NETWORK.md`; workers get theirs at spawn).
    pub chaos: Option<String>,
    /// Net transport only: shared frame-authentication passphrase.
    pub auth_key: Option<String>,
    /// Simulated per-response worker compute latency in microseconds
    /// (threaded + net transports; keeps wall-clock runs long enough
    /// for timed fault schedules to land mid-run).
    pub latency_us: u64,
    /// Election decode measurement mode (E13).
    pub election: bool,
    /// Flight recorder (tracing + evidence ledger + metrics); `None`
    /// costs nothing.
    pub recorder: Option<Arc<crate::trace::Recorder>>,
}

impl RunSpec {
    pub fn new(n: usize, f: usize, policy: PolicyKind) -> RunSpec {
        RunSpec {
            n,
            f,
            byzantine: (n - f..n).collect(),
            policy,
            attack: AttackConfig::default(),
            steps: 200,
            seed: 42,
            d: 16,
            chunk: 8,
            lr: 0.5,
            self_check: false,
            noise_std: 0.0,
            no_eliminate: false,
            compressor: None,
            unaudited_filter: None,
            transport: TransportKind::Threaded,
            shards: 1,
            gather: GatherPolicy::All,
            adversary: None,
            sim: SimConfig::default(),
            pipeline: 1,
            peers: Vec::new(),
            chaos: None,
            auth_key: None,
            latency_us: 0,
            election: false,
            recorder: None,
        }
    }

    pub fn attack(mut self, kind: AttackKind, p: f64, magnitude: f32) -> Self {
        self.attack = AttackConfig { kind, p, magnitude };
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn self_check(mut self, yes: bool) -> Self {
        self.self_check = yes;
        self
    }

    pub fn no_eliminate(mut self, yes: bool) -> Self {
        self.no_eliminate = yes;
        self
    }

    pub fn noise(mut self, std: f32) -> Self {
        self.noise_std = std;
        self
    }

    pub fn transport(mut self, kind: TransportKind) -> Self {
        self.transport = kind;
        self
    }

    pub fn shards(mut self, k: usize) -> Self {
        self.shards = k;
        self
    }

    pub fn gather(mut self, gather: GatherPolicy) -> Self {
        self.gather = gather;
        self
    }

    pub fn adversary(mut self, kind: AdversaryKind) -> Self {
        self.adversary = Some(kind);
        self
    }

    pub fn sim(mut self, sim: SimConfig) -> Self {
        self.sim = sim;
        self
    }

    pub fn pipeline(mut self, depth: usize) -> Self {
        self.pipeline = depth;
        self
    }

    pub fn peers(mut self, peers: Vec<String>) -> Self {
        self.peers = peers;
        self
    }

    pub fn chaos(mut self, spec: &str) -> Self {
        self.chaos = Some(spec.to_string());
        self
    }

    pub fn auth_key(mut self, key: &str) -> Self {
        self.auth_key = Some(key.to_string());
        self
    }

    pub fn latency_us(mut self, us: u64) -> Self {
        self.latency_us = us;
        self
    }

    pub fn compress(mut self, comp: Arc<dyn Compressor>) -> Self {
        self.compressor = Some(comp);
        self
    }

    pub fn election(mut self, yes: bool) -> Self {
        self.election = yes;
        self
    }

    pub fn recorder(mut self, rec: Arc<crate::trace::Recorder>) -> Self {
        self.recorder = Some(rec);
        self
    }

    /// Run on the native linreg workload; returns the outcome plus the
    /// planted optimum.
    pub fn run_linreg(&self) -> Result<(TrainOutcome, Vec<f32>)> {
        let mut cluster = ClusterConfig::new(self.n, self.f, self.seed);
        cluster.byzantine_ids = self.byzantine.clone();
        cluster.transport = self.transport;
        cluster.shards = self.shards;
        cluster.gather = self.gather;
        cluster.pipeline = self.pipeline;
        cluster.peers = self.peers.clone();
        cluster.chaos = self.chaos.clone();
        cluster.auth_key = self.auth_key.clone();
        cluster.latency_us = self.latency_us;
        let cfg = ExperimentConfig {
            name: "exp".into(),
            cluster,
            policy: self.policy.clone(),
            attack: self.attack.clone(),
            adversary: self.adversary,
            train: TrainConfig { steps: self.steps, lr: self.lr, ..Default::default() },
        };
        let ds = Arc::new(LinRegDataset::generate(4096, self.d, self.noise_std, self.seed));
        let w_star = ds.w_star.clone();
        let spec = ModelSpec::LinReg { d: self.d, batch: self.chunk };
        let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
        let theta0 = spec.init_theta(self.seed);
        let opts = MasterOptions {
            self_check: self.self_check,
            w_star: Some(w_star.clone()),
            no_eliminate: self.no_eliminate,
            compressor: self.compressor.clone(),
            unaudited_filter: self.unaudited_filter.clone(),
            election: self.election,
            sim: self.sim.clone(),
            recorder: self.recorder.clone(),
            net_model: Some(spec.clone()),
            ..Default::default()
        };
        let master = Master::new(cfg, opts, engine, ds, theta0, self.chunk)?;
        Ok((master.run()?, w_star))
    }
}

/// Average a measurement over several seeds.
pub fn over_seeds<F: FnMut(u64) -> Result<f64>>(seeds: std::ops::Range<u64>, mut f: F) -> Result<f64> {
    let n = (seeds.end - seeds.start) as f64;
    let mut acc = 0.0;
    for s in seeds {
        acc += f(s)?;
    }
    Ok(acc / n.max(1.0))
}
