//! E5 — the adaptive policy (§4.3): closed-form q*_t vs numeric argmin
//! over the whole (λ, p, f_t) grid, the paper's boundary conditions,
//! and the q*_t trajectory during an actual attacked training run.

use crate::config::{AttackKind, PolicyKind};
use crate::coordinator::analysis;
use crate::util::bench::{f, Table};
use crate::Result;

use super::common::RunSpec;

pub fn run(fast: bool) -> Result<()> {
    println!("\n#### E5: adaptive q*_t (Eqs. 4-5)");

    // (a) closed form vs numeric argmin
    let mut worst = 0.0f64;
    for &f_t in &[1usize, 2, 4, 8] {
        for &p in &[0.1, 0.3, 0.5, 0.9] {
            for i in 0..=10 {
                let lambda = i as f64 / 10.0;
                let closed = analysis::eq4_qstar(lambda, p, f_t);
                let numeric = analysis::eq4_qstar_numeric(lambda, p, f_t, 20_000);
                worst = worst.max((closed - numeric).abs());
            }
        }
    }
    println!("  closed-form q* vs numeric argmin: max |diff| = {worst:.2e} over 176-point grid");
    anyhow::ensure!(worst < 1e-3);

    // (b) boundary conditions from the paper
    let mut table = Table::new(&["boundary condition", "paper", "measured q*"]);
    table.row(&[
        "loss -> inf (λ -> 1)".into(),
        "q* = 1".into(),
        f(analysis::eq4_qstar(analysis::eq5_lambda(1e9), 0.5, 3)),
    ]);
    table.row(&[
        "p = 0".into(),
        "q* = 0".into(),
        f(analysis::eq4_qstar(0.8, 0.0, 3)),
    ]);
    table.row(&[
        "κ_t = f (f_t = 0)".into(),
        "q* = 0".into(),
        f(analysis::eq4_qstar(0.8, 0.5, 0)),
    ]);
    table.print("E5b (boundary conditions)");

    // (c) trajectory during an attacked linreg run: q*_t must track the
    // falling loss, then snap to 0 at full identification
    let steps = if fast { 150 } else { 400 };
    let (out, _) = RunSpec::new(9, 2, PolicyKind::Adaptive { p_assumed: 0.5 })
        .attack(AttackKind::SignFlip, 0.5, 2.0)
        .steps(steps)
        .seed(31)
        .run_linreg()?;
    let mut table = Table::new(&["iter", "loss", "lambda_t", "q_t"]);
    let iters = &out.metrics.iterations;
    let idxs: Vec<usize> = [0usize, 1, 2, 5, 10, 20, 50, steps - 1]
        .iter()
        .copied()
        .filter(|&i| i < iters.len())
        .collect();
    for &i in &idxs {
        let r = &iters[i];
        table.row(&[r.iter.to_string(), f(r.loss as f64), f(r.lambda), f(r.q)]);
    }
    table.print("E5c (q*_t trajectory, sign-flip attack, f=2)");
    println!(
        "  eliminated {:?}; final dist-to-opt {:.2e}",
        out.eliminated,
        out.metrics.iterations.last().unwrap().dist_to_opt.unwrap()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e5_fast() {
        super::run(true).unwrap();
    }
}
