//! E2 / E6 / E8 — computation-efficiency experiments.
//!
//! * E2: measured expected efficiency of the randomized scheme vs the
//!   Eq. (2) lower bound, sweeping q and f.
//! * E6: the scheme-comparison table (vanilla / DRACO / deterministic /
//!   randomized) from §2-§3.
//! * E8: the §4.1 efficiency staircase of the deterministic scheme as
//!   Byzantine workers are identified and eliminated.

use crate::config::{AttackKind, PolicyKind};
use crate::coordinator::analysis;
use crate::util::bench::{f, Table};
use crate::Result;

use super::common::RunSpec;

/// E2: efficiency vs q, measured against Eq. (2).
pub fn run_e2(fast: bool) -> Result<()> {
    println!("\n#### E2: expected computation efficiency vs Eq. (2) lower bound");
    let steps = if fast { 300 } else { 2000 };
    let mut table = Table::new(&["f", "n", "q", "eq2 bound", "measured", "bound holds"]);
    for &f_byz in &[1usize, 2, 4] {
        let n = 4 * f_byz + 1; // comfortably > 2f
        for &q in &[0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
            // worst-case adversary for the bound: always tamper so every
            // audit escalates to reactive redundancy; no_eliminate holds
            // f_t = f, the regime Eq. (2) is stated for. "Expected
            // computation efficiency" is the mean of the per-iteration
            // Definition-2 ratios.
            let (out, _) = RunSpec::new(n, f_byz, PolicyKind::Bernoulli { q })
                .attack(AttackKind::SignFlip, 1.0, 2.0)
                .steps(steps)
                .seed(7 + f_byz as u64)
                .no_eliminate(true)
                .run_linreg()?;
            let measured = out.metrics.mean_iteration_efficiency();
            let bound = analysis::eq2_expected_efficiency(q, f_byz);
            // statistical slack: audit count is binomial in q·steps
            let holds = measured + 0.05 >= bound;
            table.row(&[
                f_byz.to_string(),
                n.to_string(),
                f(q),
                f(bound),
                f(measured),
                holds.to_string(),
            ]);
        }
    }
    table.print("E2 (Eq. 2)");
    Ok(())
}

/// E6: scheme comparison table (the paper's §2 summary + §3).
pub fn run_e6(fast: bool) -> Result<()> {
    println!("\n#### E6: efficiency comparison across schemes (paper §2-§3)");
    let steps = if fast { 200 } else { 1000 };
    let mut table = Table::new(&["scheme", "f", "paper (analytic)", "measured"]);
    for &f_byz in &[1usize, 2, 4] {
        let n = 4 * f_byz + 1;
        // vanilla: efficiency 1 (and no fault tolerance at all)
        let (out, _) = RunSpec::new(n, f_byz, PolicyKind::None)
            .attack(AttackKind::SignFlip, 0.0, 1.0)
            .steps(steps)
            .run_linreg()?;
        table.row(&[
            "vanilla".into(),
            f_byz.to_string(),
            "1".into(),
            f(out.metrics.mean_iteration_efficiency()),
        ]);
        // deterministic (attackers silent so no elimination: steady state)
        let (out, _) = RunSpec::new(n, f_byz, PolicyKind::Deterministic)
            .attack(AttackKind::SignFlip, 0.0, 1.0)
            .steps(steps)
            .run_linreg()?;
        table.row(&[
            "deterministic".into(),
            f_byz.to_string(),
            format!("1/(f+1) = {}", f(analysis::deterministic_efficiency(f_byz))),
            f(out.metrics.mean_iteration_efficiency()),
        ]);
        // DRACO: proactive 2f+1 replication, analytic by construction;
        // measured = replication accounting on the same workload shape
        table.row(&[
            "DRACO [5]".into(),
            f_byz.to_string(),
            format!("1/(2f+1) = {}", f(analysis::draco_efficiency(f_byz))),
            f(crate::baselines::DracoAggregator::new(f_byz).efficiency()),
        ]);
        // randomized with δ = 0.1 target
        let q = analysis::q_for_target_inefficiency(0.1, f_byz);
        let (out, _) = RunSpec::new(n, f_byz, PolicyKind::Bernoulli { q })
            .attack(AttackKind::SignFlip, 0.0, 1.0)
            .steps(steps)
            .run_linreg()?;
        table.row(&[
            format!("randomized (δ=0.1, q={})", f(q)),
            f_byz.to_string(),
            ">= 0.9".into(),
            f(out.metrics.mean_iteration_efficiency()),
        ]);
    }
    table.print("E6 (scheme comparison)");
    Ok(())
}

/// E8: deterministic-scheme efficiency staircase 1/(f_t+1) as workers
/// are eliminated (§4.1).
pub fn run_e8(fast: bool) -> Result<()> {
    println!("\n#### E8: deterministic efficiency staircase (§4.1)");
    let steps = if fast { 60 } else { 200 };
    let f_byz = 4;
    let n = 16;
    // attackers tamper with moderate probability so eliminations spread
    // over the run instead of all landing in iteration 0
    let (out, _) = RunSpec::new(n, f_byz, PolicyKind::Deterministic)
        .attack(AttackKind::Noise, 0.25, 3.0)
        .steps(steps)
        .seed(5)
        .run_linreg()?;
    let mut table = Table::new(&["iter", "kappa_t", "f_t", "paper 1/(f_t+1)", "measured eff"]);
    let mut kappa = 0usize;
    let mut last_printed = usize::MAX;
    for r in &out.metrics.iterations {
        let f_t_before = f_byz - kappa;
        if kappa != last_printed || r.identified > 0 {
            table.row(&[
                r.iter.to_string(),
                kappa.to_string(),
                f_t_before.to_string(),
                f(analysis::deterministic_efficiency(f_t_before)),
                f(r.efficiency()),
            ]);
            last_printed = kappa;
        }
        kappa += r.identified;
    }
    table.row(&[
        "final".into(),
        out.eliminated.len().to_string(),
        (f_byz - out.eliminated.len()).to_string(),
        f(analysis::deterministic_efficiency(f_byz - out.eliminated.len())),
        f(out.metrics.iterations.last().unwrap().efficiency()),
    ]);
    table.print("E8 (efficiency staircase)");
    anyhow::ensure!(
        out.eliminated.len() == f_byz,
        "all {f_byz} persistent attackers should be eliminated, got {:?}",
        out.eliminated
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e2_fast() {
        super::run_e2(true).unwrap();
    }

    #[test]
    fn e6_fast() {
        super::run_e6(true).unwrap();
    }

    #[test]
    fn e8_fast() {
        super::run_e8(true).unwrap();
    }
}
