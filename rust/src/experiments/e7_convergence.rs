//! E7 / E10 — exact fault-tolerance (Definition 1) and the gradient-
//! filter comparison (§3).
//!
//! * E7: final distance to the planted optimum ||w_T - w*|| for each
//!   (scheme × attack) cell — the paper's claim: vanilla SGD diverges,
//!   both proposed schemes converge *exactly*.
//! * E10: the same workload aggregated by each gradient filter — the
//!   paper's claim: filters are only approximately robust (nonzero
//!   residual), and some attacks defeat some filters entirely.

use crate::config::{AttackKind, PolicyKind};
use crate::data::{Batch, Dataset, LinRegDataset};
use crate::grad::{GradientComputer, ModelSpec, NativeEngine};
use crate::linalg;
use crate::util::bench::Table;
use crate::util::rng::Pcg64;
use crate::Result;

use super::common::RunSpec;

fn sci(x: f64) -> String {
    format!("{x:.2e}")
}

/// E7: scheme × attack exactness grid.
pub fn run_e7(fast: bool) -> Result<()> {
    println!("\n#### E7: exact fault-tolerance (Def. 1): final ||w_T - w*||");
    let steps = if fast { 200 } else { 600 };
    let schemes: Vec<(&str, PolicyKind)> = vec![
        ("vanilla", PolicyKind::None),
        ("deterministic", PolicyKind::Deterministic),
        ("randomized q=0.3", PolicyKind::Bernoulli { q: 0.3 }),
        ("adaptive", PolicyKind::Adaptive { p_assumed: 0.7 }),
    ];
    let attacks = [AttackKind::SignFlip, AttackKind::Noise, AttackKind::SmallBias, AttackKind::Collude];
    let mut table = Table::new(&["scheme", "attack", "dist to w*", "eliminated", "exact?"]);
    for (name, policy) in &schemes {
        for &attack in &attacks {
            let (out, w_star) = RunSpec::new(9, 2, policy.clone())
                .attack(attack, 0.7, 2.0)
                .steps(steps)
                .seed(17)
                .run_linreg()?;
            let dist = linalg::dist2(&out.theta, &w_star) as f64;
            let exact = dist < 1e-2;
            table.row(&[
                name.to_string(),
                attack.name().into(),
                sci(dist),
                format!("{:?}", out.eliminated),
                exact.to_string(),
            ]);
            if *name != "vanilla" {
                anyhow::ensure!(exact, "{name} under {attack:?} failed: dist={dist}");
            }
        }
    }
    table.print("E7 (Def. 1 exactness grid)");
    Ok(())
}

/// E10: gradient-filter residuals under the same attacks (one-shot
/// aggregation study + a short filtered-SGD run).
pub fn run_e10(fast: bool) -> Result<()> {
    println!("\n#### E10: gradient filters are approximate (§3)");
    let d = 16usize;
    let n = 9usize;
    let f_byz = 2usize;
    let steps = if fast { 200 } else { 600 };

    // (a) one-shot: distance of filter output from the honest mean
    let mut rng = Pcg64::seeded(99);
    let truth: Vec<f32> = rng.gauss_vec(d);
    let honest: Vec<Vec<f32>> = (0..n - f_byz)
        .map(|_| truth.iter().map(|&v| v + 0.05 * rng.gauss_f32()).collect())
        .collect();
    let honest_refs: Vec<&[f32]> = honest.iter().map(|g| g.as_slice()).collect();
    let honest_mean = linalg::mean_of(&honest_refs);

    let mut table = Table::new(&["filter", "attack", "|agg - honest mean|", "exact?"]);
    for &attack in &[AttackKind::Noise, AttackKind::SmallBias, AttackKind::Collude] {
        for filt in crate::baselines::filters::all_filters() {
            let mut grads = honest.clone();
            let mut behavior = crate::coordinator::byzantine::ByzantineBehavior::new(
                crate::config::AttackConfig { kind: attack, p: 1.0, magnitude: 2.0 },
                5,
                0,
            );
            for _ in 0..f_byz {
                let mut g = truth.clone();
                let mut loss = 1.0;
                behavior.corrupt(0, &mut g, &mut loss);
                grads.push(g);
            }
            let agg = filt.aggregate(&grads, f_byz);
            let err = linalg::dist2(&agg, &honest_mean) as f64;
            table.row(&[
                filt.name().into(),
                attack.name().into(),
                sci(err),
                (err < 1e-6).to_string(),
            ]);
        }
    }
    table.print("E10a (one-shot filter residual; our schemes recover the mean bit-exactly)");

    // (b) filtered SGD on linreg vs our randomized scheme, under the
    // textbook filter-killer: f = floor((n-1)/2) colluding workers all
    // sending the SAME crafted vector. Krum scores the colluders' point
    // as maximally "central" (zero distance to each other) and keeps
    // selecting it; coordinate filters get dragged toward it.
    let n_b = 7usize;
    let f_b = 3usize;
    let ds = LinRegDataset::generate(4096, d, 0.0, 23);
    let spec = ModelSpec::LinReg { d, batch: 8 };
    let engine = NativeEngine::new(spec.clone());
    let mut table = Table::new(&["aggregator", "final dist to w*", "exact?"]);
    for filt in crate::baselines::filters::all_filters() {
        let mut theta = spec.init_theta(23);
        let mut rng = Pcg64::seeded(23);
        let mut behavior: Vec<_> = (0..f_b)
            .map(|i| {
                crate::coordinator::byzantine::ByzantineBehavior::new(
                    crate::config::AttackConfig {
                        kind: AttackKind::Collude,
                        p: 1.0,
                        magnitude: 1.0,
                    },
                    7,
                    i,
                )
            })
            .collect();
        for step in 0..steps {
            // n workers each compute a gradient on their own batch
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(n_b);
            for w in 0..n_b {
                let ids: Vec<usize> = (0..8).map(|_| rng.index(ds.len())).collect();
                let batch: Batch = ds.batch(&ids);
                let mut out = engine.grad(&theta, &batch)?;
                if w < f_b {
                    let mut loss = out.loss;
                    behavior[w].corrupt(step as u64, &mut out.grad, &mut loss);
                }
                grads.push(out.grad);
            }
            let agg = filt.aggregate(&grads, f_b);
            linalg::axpy(-0.5, &agg, &mut theta);
        }
        let dist = linalg::dist2(&theta, &ds.w_star) as f64;
        table.row(&[filt.name().into(), sci(dist), (dist < 1e-2).to_string()]);
    }
    // our randomized scheme under the identical attack for contrast
    let mut spec_run = RunSpec::new(n_b, f_b, PolicyKind::Bernoulli { q: 0.3 });
    spec_run.byzantine = (0..f_b).collect();
    let (out, w_star) = spec_run
        .attack(AttackKind::Collude, 1.0, 1.0)
        .steps(steps)
        .seed(23)
        .run_linreg()?;
    let dist = linalg::dist2(&out.theta, &w_star) as f64;
    table.row(&["r3bft randomized".into(), sci(dist), (dist < 1e-2).to_string()]);
    table.print(&format!(
        "E10b (filtered SGD vs reactive redundancy, {f_b}/{n_b} colluding attackers)"
    ));
    let _ = n;
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e7_fast() {
        super::run_e7(true).unwrap();
    }

    #[test]
    fn e10_fast() {
        super::run_e10(true).unwrap();
    }
}
