//! E3 — probability of faulty updates vs Eq. (3):
//! P(faulty update) = (1 - (1-p)^f)(1 - q).
//!
//! Byzantine workers tamper independently with probability p; the
//! oracle counts the iterations in which a tampered gradient entered
//! the parameter update. The run uses the `no_eliminate` measurement
//! mode (identify + correct, never eliminate) because Eq. (3) is
//! stated for the regime where all f Byzantine workers remain active.

use crate::config::{AttackKind, PolicyKind};
use crate::coordinator::analysis;
use crate::util::bench::{f, Table};
use crate::Result;

use super::common::RunSpec;

pub fn run(fast: bool) -> Result<()> {
    println!("\n#### E3: probability of faulty updates vs Eq. (3)");
    let steps = if fast { 400 } else { 3000 };
    let mut table = Table::new(&["f", "p", "q", "eq3 analytic", "measured", "|diff|"]);
    for &(f_byz, n) in &[(1usize, 5usize), (2, 9), (4, 17)] {
        for &p in &[0.2, 0.5] {
            for &q in &[0.0, 0.25, 0.5] {
                let (out, _) = RunSpec::new(n, f_byz, PolicyKind::Bernoulli { q })
                    .attack(AttackKind::SignFlip, p, 2.0)
                    .steps(steps)
                    .seed(11 + (f_byz * 7 + (p * 10.0) as usize + (q * 4.0) as usize) as u64)
                    .no_eliminate(true) // Eq. (3) assumes all f still active
                    .noise(0.2) // keep gradients off bit-zero
                    .run_linreg()?;
                let iters = &out.metrics.iterations;
                let faulty = iters.iter().filter(|r| r.oracle_faulty_update).count();
                let measured = faulty as f64 / iters.len().max(1) as f64;
                let analytic = analysis::eq3_prob_faulty_update(p, q, f_byz);
                table.row(&[
                    f_byz.to_string(),
                    f(p),
                    f(q),
                    f(analytic),
                    f(measured),
                    f((measured - analytic).abs()),
                ]);
            }
        }
    }
    table.print("E3 (Eq. 3)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e3_fast() {
        super::run(true).unwrap();
    }
}
