//! E14 — chaos soak: the exactness contract survives a hostile
//! network.
//!
//! Every network claim so far was measured on a polite loopback. This
//! experiment re-runs the Byzantine workload over real TCP while the
//! deterministic chaos layer ([`crate::coordinator::transport::net`])
//! injects faults on *both* directions of every link — per-frame drop,
//! bounded delay, duplication, reordering, mid-frame corruption, and
//! timed partitions — with every frame carrying a keyed MAC
//! (`--auth-key`), so a corrupted byte is an authentication failure,
//! not a silent mis-parse.
//!
//! The sweep is {drop, delay, dup+reorder, partition, corrupt} ×
//! {dense, signSGD wires} × {flat, 4 shards}, each cell under a live
//! sign-flip adversary with deterministic audits, and per cell the
//! full exactness contract is *asserted*, not just reported:
//!
//! * every liar is identified, and every elimination carries a
//!   complete evidence chain in the flight recorder's ledger;
//! * zero honest workers are eliminated;
//! * zero tampered updates enter θ (deterministic audits are exact —
//!   chaos may slow the protocol down but never lets a lie through);
//! * the run finishes every iteration — duplicated, reordered, and
//!   resent frames are deduplicated by sequence number, so no round
//!   double-counts and nothing hangs.
//!
//! Two headline figures land in `BENCH_chaos.json`: rounds to
//! identification and session reconnects as a function of the drop
//! rate, and a crash-stop demonstration — a peer whose link never
//! comes up exhausts its reconnect budget and surfaces as an in-band
//! crash-stop (chunks reassigned, never an identification, never a
//! hang).

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::config::{AttackKind, GatherPolicy, PolicyKind, TransportKind};
use crate::coordinator::compress::SignSgd;
use crate::coordinator::transport::net::server::{self, ServeOptions};
use crate::coordinator::transport::{AuthKey, ChaosSpec};
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::Result;

use super::common::RunSpec;

/// Shared frame-authentication passphrase for the whole fleet.
const AUTH: &str = "e14-chaos-soak";

/// The fault menagerie: rates are mild enough that the reconnect
/// budget (5 attempts, 25 ms base backoff) and the resend timer
/// (400 ms) always recover, so every cell must *finish* — the
/// contract under test is exactness-under-adversity, not liveness
/// limits.
const FAULTS: &[(&str, &str)] = &[
    ("drop", "drop:0.02"),
    ("delay", "delay:2ms"),
    ("dup+reorder", "dup:0.15,reorder:0.25"),
    ("partition", "partition:60ms@450ms"),
    ("corrupt", "corrupt:0.02"),
];

/// Host `n` workers on in-process threads, each serving with the
/// fleet auth key and its own seeded chaos link on the response path.
fn spawn_workers(
    n: usize,
    chaos: Option<&str>,
    auth: Option<&str>,
) -> Result<(Vec<String>, Vec<JoinHandle<()>>)> {
    let chaos = match chaos {
        Some(spec) => Some(ChaosSpec::parse(spec)?),
        None => None,
    };
    let auth = auth.map(AuthKey::from_passphrase);
    let mut peers = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        peers.push(listener.local_addr()?.to_string());
        let opts = ServeOptions { auth, chaos };
        handles.push(std::thread::spawn(move || {
            server::serve_with(listener, opts).expect("worker serve");
        }));
    }
    Ok((peers, handles))
}

/// One matrix cell's measurements (the exactness assertions happen
/// inside [`run_cell`]; a cell that reaches the table passed them).
struct Cell {
    fault: String,
    wire: &'static str,
    shards: usize,
    /// Iteration of the last liar's identification.
    identified_at: u64,
    reconnects: u64,
    final_dist: f64,
}

fn run_cell(
    fault: &str,
    chaos: &str,
    wire: Option<&'static str>,
    shards: usize,
    steps: usize,
) -> Result<Cell> {
    // the sharded plan mirrors the net integration tests: one liar per
    // shard so every per-shard budget satisfies 2 f_s < n_s
    let (n, f, byz): (usize, usize, Vec<usize>) = if shards == 1 {
        (8, 2, vec![2, 5])
    } else {
        (12, 4, vec![1, 4, 7, 10])
    };
    let (peers, workers) = spawn_workers(n, Some(chaos), Some(AUTH))?;
    let recorder = crate::trace::Recorder::new();
    let mut spec = RunSpec::new(n, f, PolicyKind::Deterministic)
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(steps)
        .noise(0.05)
        .transport(TransportKind::Net)
        .shards(shards)
        .gather(GatherPolicy::All)
        .peers(peers)
        .chaos(chaos)
        .auth_key(AUTH)
        .recorder(recorder.clone());
    spec.byzantine = byz.clone();
    if wire == Some("sign") {
        spec = spec.compress(Arc::new(SignSgd));
    }
    let label = format!("{fault} x {} x K={shards}", wire.unwrap_or("dense"));
    let (out, w_star) = spec.run_linreg()?;
    for h in workers {
        h.join().expect("worker thread");
    }

    // ---- the exactness contract, asserted per cell -----------------
    anyhow::ensure!(
        out.metrics.iterations.len() == steps,
        "{label}: run stopped at {}/{steps} iterations",
        out.metrics.iterations.len()
    );
    anyhow::ensure!(
        out.crashed.is_empty(),
        "{label}: chaos escalated to a crash: {:?}",
        out.crashed
    );
    let honest = out.eliminated.iter().filter(|w| !byz.contains(w)).count();
    anyhow::ensure!(honest == 0, "{label}: {honest} honest workers eliminated");
    let mut elim = out.eliminated.clone();
    elim.sort_unstable();
    anyhow::ensure!(elim == byz, "{label}: liars {byz:?} not all identified (got {elim:?})");
    for &w in &out.eliminated {
        anyhow::ensure!(
            recorder.evidence_for(w).iter().any(|c| c.complete()),
            "{label}: worker {w} eliminated without a complete evidence chain"
        );
    }
    anyhow::ensure!(
        out.events.oracle_faulty_updates() == 0,
        "{label}: {} tampered updates entered theta",
        out.events.oracle_faulty_updates()
    );

    let identified_at = byz
        .iter()
        .map(|&w| out.events.identification_time(w).unwrap_or(0))
        .max()
        .unwrap_or(0);
    let reconnects: u64 = out.metrics.iterations.iter().map(|r| r.net_reconnects).sum();
    Ok(Cell {
        fault: fault.to_string(),
        wire: wire.unwrap_or("dense"),
        shards,
        identified_at,
        reconnects,
        final_dist: crate::linalg::dist2(&out.theta, &w_star) as f64,
    })
}

/// One point of the headline drop-rate sweep: rounds to the last
/// identification and total session reconnects at this drop rate.
fn sweep_point(rate: f64, steps: usize) -> Result<(u64, u64)> {
    let chaos = format!("drop:{rate}");
    let cell = run_cell("drop-sweep", &chaos, None, 1, steps)?;
    Ok((cell.identified_at, cell.reconnects))
}

/// A peer whose link never comes up: the reconnect budget exhausts and
/// the worker surfaces as an in-band crash-stop while the liars are
/// still identified and the run finishes.
fn run_crash_stop(steps: usize) -> Result<(usize, u64)> {
    let n = 8;
    let victim = 6usize; // honest — a dead link must never look Byzantine
    let byz = vec![2usize, 5];
    let (mut peers, workers) = spawn_workers(n - 1, Some("drop:0.02"), Some(AUTH))?;
    // bind-then-drop: a port with no listener refuses every connect
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0")?;
        l.local_addr()?.to_string()
    };
    peers.insert(victim, dead);
    let mut spec = RunSpec::new(n, 2, PolicyKind::Deterministic)
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(steps)
        .noise(0.05)
        .transport(TransportKind::Net)
        .gather(GatherPolicy::All)
        .peers(peers)
        .chaos("drop:0.02")
        .auth_key(AUTH);
    spec.byzantine = byz.clone();
    let (out, _) = spec.run_linreg()?;
    for h in workers {
        h.join().expect("worker thread");
    }
    anyhow::ensure!(out.crashed == vec![victim], "dead link must crash-stop: {:?}", out.crashed);
    anyhow::ensure!(
        !out.eliminated.contains(&victim),
        "an exhausted link is a crash, never an identification"
    );
    let mut elim = out.eliminated.clone();
    elim.sort_unstable();
    anyhow::ensure!(elim == byz, "liars still identified around the crash (got {elim:?})");
    anyhow::ensure!(out.events.oracle_faulty_updates() == 0, "crash cell leaked a faulty update");
    anyhow::ensure!(out.metrics.iterations.len() == steps, "crash cell must finish every round");
    let reconnects: u64 = out.metrics.iterations.iter().map(|r| r.net_reconnects).sum();
    Ok((victim, reconnects))
}

pub fn run_e14(fast: bool) -> Result<()> {
    println!("\n#### E14: chaos soak — exactness over a hostile network (auth on every frame)");
    let steps = if fast { 25 } else { 80 };
    let mut table = Table::new(&[
        "fault",
        "wire",
        "K",
        "identified at",
        "reconnects",
        "final dist",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let push = |table: &mut Table, rows: &mut Vec<Json>, cell: Cell| {
        table.row(&[
            cell.fault.clone(),
            cell.wire.to_string(),
            cell.shards.to_string(),
            cell.identified_at.to_string(),
            cell.reconnects.to_string(),
            format!("{:.2e}", cell.final_dist),
        ]);
        let mut obj = BTreeMap::new();
        obj.insert("fault".to_string(), Json::Str(cell.fault));
        obj.insert("wire".to_string(), Json::Str(cell.wire.to_string()));
        obj.insert("shards".to_string(), Json::Num(cell.shards as f64));
        obj.insert("identified_at".to_string(), Json::Num(cell.identified_at as f64));
        obj.insert("reconnects".to_string(), Json::Num(cell.reconnects as f64));
        obj.insert("final_dist".to_string(), Json::Num(cell.final_dist));
        obj.insert("exactness_held".to_string(), Json::Bool(true)); // asserted in run_cell
        rows.push(Json::Obj(obj));
    };
    // the full matrix crosses shard plans too; fast keeps the flat
    // cross and probes the sharded fleet with the two faults that
    // stress it hardest (resends across shard boundaries, partitions)
    let shard_plans: &[usize] = if fast { &[1] } else { &[1, 4] };
    for &shards in shard_plans {
        for &(fault, chaos) in FAULTS {
            for wire in [None, Some("sign")] {
                let cell = run_cell(fault, chaos, wire, shards, steps)?;
                push(&mut table, &mut rows, cell);
            }
        }
    }
    if fast {
        for &(fault, chaos) in &[FAULTS[0], FAULTS[3]] {
            let cell = run_cell(fault, chaos, None, 4, steps)?;
            push(&mut table, &mut rows, cell);
        }
    }
    table.print("E14 (chaos matrix over real TCP, deterministic audits, seed 42)");
    println!(
        "\nevery cell above passed the exactness contract: all liars identified \
         with complete evidence chains, zero honest eliminations, zero tampered \
         updates in theta, every iteration finished — chaos slows the protocol \
         down (reconnects, resends) but never changes what it decides."
    );

    // ---- headline: identification cost and reconnects vs drop rate ----
    let rates: &[f64] = if fast { &[0.0, 0.02] } else { &[0.0, 0.02, 0.05] };
    let mut sweep_rows: Vec<Json> = Vec::new();
    println!();
    for &rate in rates {
        let (identified_at, reconnects) = sweep_point(rate, steps)?;
        println!(
            "drop rate {rate:<5}: last liar identified at round {identified_at}, \
             {reconnects} session reconnects"
        );
        let mut obj = BTreeMap::new();
        obj.insert("drop_rate".to_string(), Json::Num(rate));
        obj.insert("identified_at".to_string(), Json::Num(identified_at as f64));
        obj.insert("reconnects".to_string(), Json::Num(reconnects as f64));
        sweep_rows.push(Json::Obj(obj));
    }

    // ---- exhausted links are crash-stops, never hangs ------------------
    let (victim, crash_reconnects) = run_crash_stop(steps)?;
    println!(
        "\ndead peer (worker {victim}): reconnect budget exhausted -> in-band \
         crash-stop, chunks reassigned, liars still identified, run finished \
         every round ({crash_reconnects} reconnects elsewhere in the fleet)"
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("chaos_net".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(format!(
            "linreg d=16 chunk=8 noise=0.05 transport=net(127.0.0.1) auth=on \
             policy=deterministic attack=sign_flip p=1.0 magnitude=2.0 \
             gather=all steps={steps} seed=42"
        )),
    );
    doc.insert("results".to_string(), Json::Arr(rows));
    doc.insert("drop_sweep".to_string(), Json::Arr(sweep_rows));
    let mut crash = BTreeMap::new();
    crash.insert("victim".to_string(), Json::Num(victim as f64));
    crash.insert("crash_stopped".to_string(), Json::Bool(true));
    crash.insert("reconnects".to_string(), Json::Num(crash_reconnects as f64));
    doc.insert("dead_peer".to_string(), Json::Obj(crash));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_chaos.json", &json) {
        Ok(()) => println!("wrote BENCH_chaos.json"),
        Err(e) => eprintln!("failed to write BENCH_chaos.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e14_fast() {
        super::run_e14(true).unwrap();
    }
}
