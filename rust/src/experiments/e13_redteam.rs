//! E13 — red-team robustness matrix: coordinated adversary strategies
//! vs audit policies.
//!
//! Every attacker the paper's analysis was measured against so far was
//! a stateless per-worker coin. This experiment sweeps the
//! [`crate::adversary`] strategies (plus the stateless sign-flip
//! baseline) against the audit policies (bernoulli / deterministic /
//! selective / latency-selective), single-master and sharded, in
//! deterministic virtual time, and reports per cell:
//!
//! * **rounds to identification** — the last colluder's
//!   identification time (the paper's almost-sure-identification
//!   claim, measured; "-" when nothing was ever identified, which for
//!   a coordinated adversary can mean it never risked a tamper);
//! * **audit cost** — audited rounds and total audited chunks at the
//!   shared q budget;
//! * **damage** — tampered updates that entered θ before elimination
//!   (oracle count), and the final distance to the planted optimum
//!   (post-elimination convergence).
//!
//! The sweep is written to `BENCH_adversary.json`. A second pass runs
//! the sleeper-vs-stateless comparison over several seeds and checks
//! the headline claim: **a warm-up adversary is strictly costlier to
//! identify than a stateless one at equal q budget** (nothing can be
//! identified before the strike begins), while the exactness property
//! — zero honest eliminations, no tampered updates after the last
//! elimination — holds in every cell (`tests/test_adversary.rs`
//! asserts it per strategy; here it is re-checked across the matrix).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{AdversaryKind, AttackKind, GatherPolicy, PolicyKind, TransportKind};
use crate::coordinator::compress::SignSgd;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::Result;

use super::common::RunSpec;

/// One matrix cell's measurements.
struct Cell {
    attacker: String,
    policy: String,
    shards: usize,
    /// Iteration of the *last* colluder identification (None when no
    /// colluder was ever identified).
    identified_at: Option<u64>,
    audit_rounds: usize,
    audited_chunks: usize,
    faulty_updates: usize,
    final_dist: f64,
    honest_eliminated: usize,
    /// Every elimination carried a complete evidence chain (detection
    /// hashes → reactive top-up → 2f_t+1 vote) in the flight
    /// recorder's ledger. Vacuously true when nothing was eliminated.
    evidence_complete: bool,
}

const N: usize = 16;
const F: usize = 2;
/// Byzantine ids spread so a 4-shard plan keeps 2f_s < n_s per shard.
const BYZ: [usize; 2] = [6, 14];

fn run_cell(
    attacker_name: &str,
    adversary: Option<AdversaryKind>,
    policy_name: &str,
    policy: PolicyKind,
    shards: usize,
    steps: usize,
) -> Result<Cell> {
    let mut spec = RunSpec::new(N, F, policy)
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(steps)
        .noise(0.05) // keep gradients away from bit-zero (footnote 2)
        .transport(TransportKind::Sim)
        .shards(shards)
        .gather(GatherPolicy::All);
    spec.byzantine = BYZ.to_vec();
    if let Some(kind) = adversary {
        spec = spec.adversary(kind);
    }
    // flight recorder attached per cell: the evidence ledger must
    // justify every elimination the matrix reports
    let recorder = crate::trace::Recorder::new();
    spec = spec.recorder(recorder.clone());
    let (out, w_star) = spec.run_linreg()?;
    for &w in &out.eliminated {
        let chains = recorder.evidence_for(w);
        anyhow::ensure!(
            chains.iter().any(|c| c.complete()),
            "worker {w} eliminated without a complete evidence chain \
             (detection → reactive top-up → vote) under {attacker_name} x {policy_name}"
        );
    }
    let identified_at = BYZ
        .iter()
        .map(|&w| out.events.identification_time(w))
        .collect::<Option<Vec<u64>>>()
        .map(|ts| ts.into_iter().max().unwrap_or(0));
    let audit_rounds = out.metrics.iterations.iter().filter(|r| r.audited).count();
    let audited_chunks: usize = out.metrics.iterations.iter().map(|r| r.audited_chunks).sum();
    let honest_eliminated =
        out.eliminated.iter().filter(|w| !BYZ.contains(w)).count();
    Ok(Cell {
        attacker: attacker_name.to_string(),
        policy: policy_name.to_string(),
        shards,
        identified_at,
        audit_rounds,
        audited_chunks,
        faulty_updates: out.events.oracle_faulty_updates(),
        final_dist: crate::linalg::dist2(&out.theta, &w_star) as f64,
        honest_eliminated,
        evidence_complete: true, // ensured above, per elimination
    })
}

/// Mean identification time of the last colluder over several seeds
/// (runs that never identify count as the full horizon — an
/// underestimate that only strengthens a ">" comparison against it).
fn mean_identification(
    adversary: Option<AdversaryKind>,
    q: f64,
    steps: usize,
    seeds: std::ops::Range<u64>,
) -> Result<f64> {
    let trials = (seeds.end - seeds.start).max(1) as f64;
    let mut acc = 0.0;
    for seed in seeds {
        let mut spec = RunSpec::new(N, F, PolicyKind::Bernoulli { q })
            .attack(AttackKind::SignFlip, 1.0, 2.0)
            .steps(steps)
            .seed(seed)
            .noise(0.05)
            .transport(TransportKind::Sim);
        spec.byzantine = BYZ.to_vec();
        if let Some(kind) = adversary {
            spec = spec.adversary(kind);
        }
        let (out, _) = spec.run_linreg()?;
        let last = BYZ
            .iter()
            .map(|&w| out.events.identification_time(w).unwrap_or(steps as u64))
            .max()
            .unwrap_or(0);
        acc += last as f64;
    }
    Ok(acc / trials)
}

pub fn run_e13(fast: bool) -> Result<()> {
    println!("\n#### E13: red-team matrix — coordinated adversaries vs audit policies");
    let steps = if fast { 150 } else { 400 };
    let q = 0.2;
    let policies: Vec<(&str, PolicyKind)> = vec![
        ("bernoulli", PolicyKind::Bernoulli { q }),
        ("deterministic", PolicyKind::Deterministic),
        ("selective", PolicyKind::Selective { q_base: q }),
        ("latency-selective", PolicyKind::LatencySelective { q_base: q }),
    ];
    let attackers: Vec<(String, Option<AdversaryKind>)> =
        std::iter::once(("sign_flip (stateless)".to_string(), None))
            .chain(AdversaryKind::ALL.iter().map(|k| (k.describe(), Some(*k))))
            .collect();

    let mut table = Table::new(&[
        "attacker",
        "policy",
        "K",
        "identified at",
        "audit rounds",
        "audited chunks",
        "faulty updates",
        "final dist",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for &shards in &[1usize, 4] {
        for (attacker_name, adversary) in &attackers {
            for (policy_name, policy) in &policies {
                let cell = run_cell(
                    attacker_name,
                    *adversary,
                    policy_name,
                    policy.clone(),
                    shards,
                    steps,
                )?;
                anyhow::ensure!(
                    cell.honest_eliminated == 0,
                    "exactness violated: {} honest workers eliminated under {} x {}",
                    cell.honest_eliminated,
                    cell.attacker,
                    cell.policy
                );
                table.row(&[
                    cell.attacker.clone(),
                    cell.policy.clone(),
                    shards.to_string(),
                    cell.identified_at
                        .map(|t| t.to_string())
                        .unwrap_or_else(|| "-".into()),
                    cell.audit_rounds.to_string(),
                    cell.audited_chunks.to_string(),
                    cell.faulty_updates.to_string(),
                    format!("{:.2e}", cell.final_dist),
                ]);
                let mut obj = BTreeMap::new();
                obj.insert("attacker".to_string(), Json::Str(cell.attacker));
                obj.insert("policy".to_string(), Json::Str(cell.policy));
                obj.insert("shards".to_string(), Json::Num(cell.shards as f64));
                obj.insert("q".to_string(), Json::Num(q));
                obj.insert("steps".to_string(), Json::Num(steps as f64));
                obj.insert(
                    "identified_at".to_string(),
                    cell.identified_at.map(|t| Json::Num(t as f64)).unwrap_or(Json::Null),
                );
                obj.insert("audit_rounds".to_string(), Json::Num(cell.audit_rounds as f64));
                obj.insert(
                    "audited_chunks".to_string(),
                    Json::Num(cell.audited_chunks as f64),
                );
                obj.insert(
                    "faulty_updates".to_string(),
                    Json::Num(cell.faulty_updates as f64),
                );
                obj.insert("final_dist".to_string(), Json::Num(cell.final_dist));
                obj.insert(
                    "evidence_complete".to_string(),
                    Json::Bool(cell.evidence_complete),
                );
                rows.push(Json::Obj(obj));
            }
        }
    }
    table.print("E13 (robustness matrix, deterministic virtual time, seed 42)");
    println!(
        "\nreading the matrix: '-' under deterministic x assignment-aware is the \
         adversary going *silent* — with r = f_t+1 every chunk keeps an honest \
         copy, so no tamper is ever safe and no damage is done (0 faulty \
         updates); everywhere an attacker keeps lying, the colluders are \
         identified and the run converges (final dist ~ the fault-free run)."
    );

    // ---- headline claim: warm-up beats stateless at equal q budget ------
    let trials = if fast { 3u64 } else { 10 };
    let sleeper = AdversaryKind::Sleeper { warmup: 15 };
    let q_cmp = 0.3;
    let stateless_mean = mean_identification(None, q_cmp, steps, 1000..1000 + trials)?;
    let sleeper_mean = mean_identification(Some(sleeper), q_cmp, steps, 1000..1000 + trials)?;
    println!(
        "\nrounds-to-identification at equal q = {q_cmp} budget over {trials} seeds: \
         stateless sign-flip {stateless_mean:.1}, sleeper:15 {sleeper_mean:.1} \
         (the sleeper cannot be identified before its strike at round 15)"
    );
    anyhow::ensure!(
        sleeper_mean > stateless_mean,
        "sleeper ({sleeper_mean:.1}) must be costlier to identify than stateless \
         ({stateless_mean:.1}) at equal q budget"
    );

    // ---- compressed symbols: exactness survives bit-packed wires --------
    // Workers send signSGD-packed bytes; detection/identification
    // compare the packed representation, so the exactness guarantee
    // (zero honest eliminations, convergence after the last
    // elimination) must hold under every coordinated strategy exactly
    // as it does dense. The election decode (per-bit replica majority)
    // is measured alongside as a *statistical* robustness number only —
    // it never feeds detection.
    println!(
        "\ncompressed symbols (signSGD wires, bernoulli q = {q}): exact decode \
         keeps the exactness guarantee per strategy; election decode measured \
         for statistical robustness only"
    );
    let mut ctable = Table::new(&[
        "attacker",
        "identified at",
        "faulty updates",
        "bytes/round",
        "final dist (exact)",
        "final dist (election)",
    ]);
    let mut crows: Vec<Json> = Vec::new();
    for (attacker_name, adversary) in &attackers {
        let mut spec = RunSpec::new(N, F, PolicyKind::Bernoulli { q })
            .attack(AttackKind::SignFlip, 1.0, 2.0)
            .steps(steps)
            .noise(0.05)
            .transport(TransportKind::Sim)
            .compress(Arc::new(SignSgd));
        spec.byzantine = BYZ.to_vec();
        if let Some(kind) = adversary {
            spec = spec.adversary(*kind);
        }
        let election_spec = spec.clone().election(true);
        // the ledger must justify eliminations on packed wires too:
        // the chain hashes are over the wire bytes detection compared
        let recorder = crate::trace::Recorder::new();
        spec = spec.recorder(recorder.clone());
        let (out, w_star) = spec.run_linreg()?;
        let honest_eliminated = out.eliminated.iter().filter(|w| !BYZ.contains(w)).count();
        anyhow::ensure!(
            honest_eliminated == 0,
            "exactness violated under compressed symbols: {honest_eliminated} honest \
             workers eliminated under {attacker_name}"
        );
        for &w in &out.eliminated {
            anyhow::ensure!(
                recorder.evidence_for(w).iter().any(|c| c.complete()),
                "worker {w} eliminated without a complete evidence chain under \
                 compressed symbols x {attacker_name}"
            );
        }
        let identified_at = BYZ
            .iter()
            .map(|&w| out.events.identification_time(w))
            .collect::<Option<Vec<u64>>>()
            .map(|ts| ts.into_iter().max().unwrap_or(0));
        let mean_bytes = out
            .metrics
            .iterations
            .iter()
            .map(|r| r.bytes_round as f64)
            .sum::<f64>()
            / out.metrics.iterations.len().max(1) as f64;
        let exact_dist = crate::linalg::dist2(&out.theta, &w_star) as f64;
        let (eout, ew_star) = election_spec.run_linreg()?;
        let election_dist = crate::linalg::dist2(&eout.theta, &ew_star) as f64;
        ctable.row(&[
            attacker_name.clone(),
            identified_at.map(|t| t.to_string()).unwrap_or_else(|| "-".into()),
            out.events.oracle_faulty_updates().to_string(),
            format!("{mean_bytes:.0}"),
            format!("{exact_dist:.2e}"),
            format!("{election_dist:.2e}"),
        ]);
        let mut obj = BTreeMap::new();
        obj.insert("attacker".to_string(), Json::Str(attacker_name.clone()));
        obj.insert(
            "identified_at".to_string(),
            identified_at.map(|t| Json::Num(t as f64)).unwrap_or(Json::Null),
        );
        obj.insert(
            "faulty_updates".to_string(),
            Json::Num(out.events.oracle_faulty_updates() as f64),
        );
        obj.insert("bytes_round_mean".to_string(), Json::Num(mean_bytes));
        obj.insert("final_dist_exact".to_string(), Json::Num(exact_dist));
        obj.insert("final_dist_election".to_string(), Json::Num(election_dist));
        crows.push(Json::Obj(obj));
    }
    ctable.print("E13 (signSGD compressed symbols, deterministic virtual time, seed 42)");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("adversary_redteam".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(format!(
            "linreg d=16 chunk=8 noise=0.05 transport=sim n={N} f={F} byz={BYZ:?} \
             gather=all steps={steps} q={q} magnitude=2.0 seed=42"
        )),
    );
    doc.insert("results".to_string(), Json::Arr(rows));
    let mut cmp = BTreeMap::new();
    cmp.insert("q".to_string(), Json::Num(q_cmp));
    cmp.insert("seeds".to_string(), Json::Num(trials as f64));
    cmp.insert("stateless_mean_identification".to_string(), Json::Num(stateless_mean));
    cmp.insert("sleeper15_mean_identification".to_string(), Json::Num(sleeper_mean));
    doc.insert("sleeper_vs_stateless".to_string(), Json::Obj(cmp));
    doc.insert("compressed_symbols".to_string(), Json::Arr(crows));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_adversary.json", &json) {
        Ok(()) => println!("wrote BENCH_adversary.json"),
        Err(e) => eprintln!("failed to write BENCH_adversary.json: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e13_fast() {
        super::run_e13(true).unwrap();
    }
}
