//! Executable reproductions of every quantitative claim in the paper
//! (the experiment index in DESIGN.md). Each submodule prints the
//! paper's analytical row next to the measured row; `cargo bench` and
//! `r3bft experiment <id>` both dispatch here.

pub mod common;
pub mod e1_fig2;
pub mod e2_efficiency;
pub mod e3_faulty_updates;
pub mod e4_identification;
pub mod e5_adaptive;
pub mod e7_convergence;
pub mod e11_generalizations;
pub mod e13_redteam;
pub mod e14_chaos;

use crate::Result;

/// Run one experiment by id ("e1".."e14"; some ids share a module).
/// `fast` shrinks iteration counts for smoke runs.
pub fn run(id: &str, fast: bool) -> Result<()> {
    match id {
        "e1" => e1_fig2::run(),
        "e2" => e2_efficiency::run_e2(fast),
        "e3" => e3_faulty_updates::run(fast),
        "e4" => e4_identification::run_e4(fast),
        "e5" => e5_adaptive::run(fast),
        "e6" => e2_efficiency::run_e6(fast),
        "e7" => e7_convergence::run_e7(fast),
        "e8" => e2_efficiency::run_e8(fast),
        "e9" => e4_identification::run_e9(fast),
        "e10" => e7_convergence::run_e10(fast),
        "e11" => e11_generalizations::run_e11(fast),
        "e12" => e11_generalizations::run_e12(fast),
        "e13" => e13_redteam::run_e13(fast),
        "e14" => e14_chaos::run_e14(fast),
        "all" => {
            for id in [
                "e1", "e2", "e3", "e4", "e5", "e6", "e7", "e8", "e9", "e10", "e11", "e12", "e13",
                "e14",
            ] {
                run(id, fast)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}' (e1..e14 or all)"),
    }
}
