//! E4 / E9 — almost-sure identification of Byzantine workers.
//!
//! * E4: empirical survival curve of an unidentified Byzantine worker
//!   vs the paper's bound (1 - q p_i)^t (§4.2), over many seeds.
//! * E9: the §5 generalizations — selective fault-checks driven by
//!   reliability scores, and master self-checks — compared with the
//!   plain Bernoulli policy on identification latency and audit cost.

use crate::config::{AttackKind, PolicyKind};
use crate::coordinator::analysis;
use crate::util::bench::{f, Table};
use crate::Result;

use super::common::RunSpec;

/// E4: survival probability vs bound.
pub fn run_e4(fast: bool) -> Result<()> {
    println!("\n#### E4: identification survival vs (1-qp)^t bound (§4.2)");
    let trials = if fast { 30 } else { 200 };
    let steps = 400usize;
    let q = 0.2;
    let p = 0.3;
    let mut id_times: Vec<u64> = Vec::new();
    let mut unidentified = 0usize;
    for seed in 0..trials {
        let (out, _) = RunSpec::new(5, 1, PolicyKind::Bernoulli { q })
            .attack(AttackKind::SignFlip, p, 2.0)
            .steps(steps)
            .seed(1000 + seed as u64)
            .run_linreg()?;
        match out.events.identification_time(4) {
            Some(t) => id_times.push(t),
            None => unidentified += 1,
        }
    }
    let mut table = Table::new(&["t", "bound (1-qp)^t", "measured survival"]);
    for &t in &[5u64, 10, 25, 50, 100, 200, 399] {
        let surv = (id_times.iter().filter(|&&x| x > t).count() + unidentified) as f64
            / trials as f64;
        table.row(&[
            t.to_string(),
            f(analysis::identification_survival_bound(q, p, t)),
            f(surv),
        ]);
    }
    table.print("E4 (identification bound)");
    println!(
        "identified in {}/{} trials; mean identification time {:.1} iters",
        trials - unidentified,
        trials,
        id_times.iter().sum::<u64>() as f64 / id_times.len().max(1) as f64
    );
    if unidentified > 0 {
        println!(
            "note: {unidentified} run(s) converged to the exact optimum (gradients \
             bit-zero) before an audited tamper; a sign-flip of a zero gradient is \
             numerically the zero gradient, i.e. the attacker became harmless — \
             exactly the paper's footnote 2 (\"a Byzantine worker that eventually \
             stops sending faulty gradients poses no harm\")."
        );
    }
    Ok(())
}

/// E9: selective checks + self-check generalizations (§5).
pub fn run_e9(fast: bool) -> Result<()> {
    println!("\n#### E9: §5 generalizations — selective checks & master self-check");
    let trials = if fast { 10 } else { 50 };
    let steps = 600usize;
    let mut table = Table::new(&[
        "policy",
        "mean ident. time",
        "identified rate",
        "mean efficiency",
    ]);
    let policies: Vec<(&str, PolicyKind, bool)> = vec![
        ("bernoulli q=0.15", PolicyKind::Bernoulli { q: 0.15 }, false),
        ("selective q_base=0.15", PolicyKind::Selective { q_base: 0.15 }, false),
        (
            "selective + self-check",
            PolicyKind::Selective { q_base: 0.15 },
            true,
        ),
    ];
    for (name, policy, self_check) in policies {
        let mut times = Vec::new();
        let mut found = 0usize;
        let mut eff = 0.0;
        for seed in 0..trials {
            let (out, _) = RunSpec::new(8, 2, policy.clone())
                .attack(AttackKind::Noise, 0.4, 2.0)
                .steps(steps)
                .seed(2000 + seed as u64)
                .self_check(self_check)
                .run_linreg()?;
            eff += out.metrics.average_efficiency();
            let mut all = true;
            for &w in &[6usize, 7] {
                match out.events.identification_time(w) {
                    Some(t) => times.push(t as f64),
                    None => all = false,
                }
            }
            found += all as usize;
        }
        table.row(&[
            name.into(),
            f(times.iter().sum::<f64>() / times.len().max(1) as f64),
            f(found as f64 / trials as f64),
            f(eff / trials as f64),
        ]);
    }
    table.print("E9 (selective / self-check)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e4_fast() {
        super::run_e4(true).unwrap();
    }

    #[test]
    fn e9_fast() {
        super::run_e9(true).unwrap();
    }
}
