//! E11 / E12 — the remaining §2.1/§5 generalizations, executable.
//!
//! * E11: **compressed gradients** — run the full randomized protocol
//!   with top-k and signSGD symbol compression. Detection/voting work
//!   on the compressed wire form (honest compressors are
//!   deterministic); the table reports communication savings,
//!   identification, and the residual error each lossy compressor
//!   itself introduces (separate from Byzantine faults).
//! * E12: **hybrid filter + randomized coding** — unaudited iterations
//!   aggregate through a lightweight gradient filter (the DETOX-style
//!   idea the paper cites), bounding the damage between audits.

use std::sync::Arc;

use crate::baselines::filters::{MedianFilter, TrimmedMeanFilter};
use crate::config::{AttackKind, PolicyKind};
use crate::coordinator::compress::{Compressor, Dense, SignSgd, TopK};
use crate::linalg;
use crate::util::bench::{f, Table};
use crate::Result;

use super::common::RunSpec;

/// E11: compressed-gradient protocol runs.
pub fn run_e11(fast: bool) -> Result<()> {
    println!("\n#### E11: compressed-gradient symbols (§2.1/§5)");
    let steps = if fast { 300 } else { 800 };
    let d = 64usize;
    let mut table = Table::new(&[
        "compressor",
        "wire bytes/symbol",
        "compression",
        "identified",
        "final dist to w*",
        "faulty-update rate",
    ]);
    let compressors: Vec<(Arc<dyn Compressor>, &str)> = vec![
        (Arc::new(Dense), "dense"),
        (Arc::new(TopK { k: 8 }), "top-8"),
        (Arc::new(SignSgd), "signSGD"),
    ];
    for (comp, name) in compressors {
        let mut spec = RunSpec::new(9, 2, PolicyKind::Bernoulli { q: 0.3 });
        spec.d = d;
        spec.lr = if name == "signSGD" { 0.02 } else { 0.3 };
        let mut spec = spec.attack(AttackKind::SignFlip, 0.7, 2.0).steps(steps).seed(29);
        spec.compressor = Some(comp.clone());
        let (out, w_star) = spec.run_linreg()?;
        let dist = linalg::dist2(&out.theta, &w_star) as f64;
        table.row(&[
            name.into(),
            comp.wire_bytes(d).to_string(),
            format!("{:.1}x", comp.ratio(d)),
            format!("{:?}", out.eliminated),
            format!("{dist:.2e}"),
            f(out.metrics.faulty_update_rate()),
        ]);
    }
    table.print("E11 (compressed symbols; dense is exact, top-k/signSGD add their own lossy bias)");
    Ok(())
}

/// E12: hybrid gradient-filter + randomized coding.
pub fn run_e12(fast: bool) -> Result<()> {
    println!("\n#### E12: hybrid filter + randomized coding (§5, DETOX-style)");
    let steps = if fast { 300 } else { 800 };
    // low q so plenty of unaudited iterations are exposed to tampering
    let q = 0.05;
    let mut table = Table::new(&[
        "unaudited aggregation",
        "faulty-update damage (mean dist during run)",
        "final dist to w*",
        "identified",
    ]);
    let cases: Vec<(&str, Option<Arc<dyn crate::baselines::GradientFilter>>)> = vec![
        ("plain mean (paper §4.2)", None),
        ("median filter", Some(Arc::new(MedianFilter))),
        ("trimmed-mean filter", Some(Arc::new(TrimmedMeanFilter))),
    ];
    for (name, filter) in cases {
        let mut spec = RunSpec::new(9, 2, PolicyKind::Bernoulli { q })
            .attack(AttackKind::Noise, 0.8, 3.0)
            .steps(steps)
            .seed(31);
        spec.unaudited_filter = filter;
        let (out, w_star) = spec.run_linreg()?;
        // mean distance over the run: how much tampering hurt while the
        // attackers were still active
        let mean_dist: f64 = out
            .metrics
            .iterations
            .iter()
            .filter_map(|r| r.dist_to_opt)
            .map(|d| d as f64)
            .sum::<f64>()
            / out.metrics.iterations.len() as f64;
        let final_dist = linalg::dist2(&out.theta, &w_star) as f64;
        table.row(&[
            name.into(),
            format!("{mean_dist:.3}"),
            format!("{final_dist:.2e}"),
            format!("{:?}", out.eliminated),
        ]);
    }
    table.print("E12 (hybrid: filters bound the damage between audits; identification still exact)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e11_fast() {
        super::run_e11(true).unwrap();
    }

    #[test]
    fn e12_fast() {
        super::run_e12(true).unwrap();
    }
}
