//! E1 — executable Figure 2: the n=3, f=1 linear-code worked example.
//!
//! Reproduces the exact narrative of the figure: honest encoding, the
//! three reconstructions agreeing, a Byzantine worker 3 sending c != c3
//! making them disagree (detection), and the reactive relay round
//! identifying worker 3 by majority voting.

use crate::coordinator::codes::{CheckOutcome, Fig2Code};
use crate::util::bench::Table;
use crate::util::rng::Pcg64;
use crate::Result;

pub fn run() -> Result<()> {
    println!("\n#### E1: Figure 2 worked example (n=3, f=1, linear detection code)");
    let mut rng = Pcg64::seeded(2024);
    let d = 4;
    let g1 = rng.gauss_vec(d);
    let g2 = rng.gauss_vec(d);
    let g3 = rng.gauss_vec(d);
    let sum: Vec<f32> = (0..d).map(|i| g1[i] + g2[i] + g3[i]).collect();

    let [c1, c2, c3] = Fig2Code::encode(&g1, &g2, &g3);
    let honest_detect = Fig2Code::detect(&c1, &c2, &c3, 1e-5);

    let mut table = Table::new(&["scenario", "paper says", "measured"]);
    table.row(&[
        "honest symbols".into(),
        "reconstructions agree".into(),
        format!("{honest_detect:?}"),
    ]);

    // reconstruction correctness: all three equal g1+g2+g3
    let [r1, r2, r3] = Fig2Code::reconstructions(&c1, &c2, &c3);
    let max_err = [&r1, &r2, &r3]
        .iter()
        .flat_map(|r| r.iter().zip(sum.iter()).map(|(a, b)| (a - b).abs()))
        .fold(0.0f32, f32::max);
    table.row(&[
        "c1+c2 = -(c2+c3) = (c1-c3)/2".into(),
        "= Σ g_i exactly".into(),
        format!("max err {max_err:.2e}"),
    ]);

    // worker 3 Byzantine: detection fires for any c != c3
    let mut bad_c3 = c3.clone();
    bad_c3[0] += 1.0;
    let byz_detect = Fig2Code::detect(&c1, &c2, &bad_c3, 1e-5);
    table.row(&[
        "worker 3 sends c != c3".into(),
        "fault detected".into(),
        format!("{byz_detect:?}"),
    ]);
    anyhow::ensure!(byz_detect == CheckOutcome::FaultDetected);

    // reactive relay round: u1 = (c2, c3), u2 = (c3, c1), u3 = (c1, c2)
    let honest = [c1.clone(), c2.clone(), c3.clone()];
    let mut claims: [[Vec<f32>; 3]; 3] = std::array::from_fn(|_| honest.clone());
    claims[2][2] = bad_c3; // worker 3 keeps lying about its own symbol
    let identified = Fig2Code::identify(&claims, 1e-5);
    table.row(&[
        "reactive redundancy + vote".into(),
        "worker 3 identified".into(),
        format!("workers {identified:?}"),
    ]);
    anyhow::ensure!(identified == vec![2]);

    table.print("E1 (Fig. 2)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn e1_runs() {
        super::run().unwrap();
    }
}
