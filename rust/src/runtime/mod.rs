//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The interchange format is **HLO text** (not a serialized
//! `HloModuleProto`): jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`).

mod client;
mod manifest;

pub use client::{ExecutionStats, HostTensor, Runtime};
pub use manifest::{ArtifactManifest, ArtifactSpec, Dtype, TensorSpec};
