//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute
//! many times from the coordinator's hot path.
//!
//! ## Thread-safety
//!
//! The `xla` crate's handles (`PjRtClient` is an `Rc`, executables are
//! raw PJRT pointers) are `!Send`/`!Sync`. All of them are confined to
//! the private `Inner` struct and touched exclusively under the
//! `Mutex`, which serializes every reference-count mutation and every
//! PJRT call; the PJRT C API itself is thread-safe. Under that
//! invariant the manual `Send`/`Sync` impls below are sound. The lock
//! also matches the hardware reality: one CPU PJRT device, so
//! concurrent executions would serialize inside XLA anyway.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context};

use super::manifest::{ArtifactManifest, ArtifactSpec, Dtype};
use crate::Result;

/// Typed host tensor handed to / returned from an execution.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            HostTensor::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            HostTensor::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(_) => Dtype::F32,
            HostTensor::I32(_) => Dtype::I32,
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// Cumulative execution counters (exposed by `r3bft inspect`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecutionStats {
    pub executions: u64,
    pub total_exec_ns: u64,
    pub compilations: u64,
    pub total_compile_ns: u64,
}

impl ExecutionStats {
    pub fn mean_exec_us(&self) -> f64 {
        if self.executions == 0 {
            0.0
        } else {
            self.total_exec_ns as f64 / self.executions as f64 / 1e3
        }
    }
}

/// A compiled artifact plus its manifest signature (module-private: all
/// execution goes through [`Runtime::run`]).
struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, s) in inputs.iter().zip(self.spec.inputs.iter()) {
            if t.len() != s.elements() {
                bail!(
                    "artifact '{}' input '{}': expected {} elements {:?}, got {}",
                    self.spec.name,
                    s.name,
                    s.elements(),
                    s.shape,
                    t.len()
                );
            }
            if t.dtype() != s.dtype {
                bail!(
                    "artifact '{}' input '{}': dtype mismatch",
                    self.spec.name,
                    s.name
                );
            }
            literals.push(t.to_literal(&s.shape)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, s) in parts.into_iter().zip(self.spec.outputs.iter()) {
            let t = match s.dtype {
                Dtype::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
                Dtype::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
            };
            if t.len() != s.elements() {
                bail!(
                    "artifact '{}' output '{}': expected {} elements, got {}",
                    self.spec.name,
                    s.name,
                    s.elements(),
                    t.len()
                );
            }
            out.push(t);
        }
        Ok(out)
    }
}

/// All `!Send` xla state lives here, only ever touched under the lock.
struct Inner {
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
}

/// The process-wide PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    inner: Mutex<Inner>,
    pub manifest: ArtifactManifest,
    stats: Mutex<ExecutionStats>,
}

// SAFETY: every xla handle is confined to `Inner` behind the Mutex; no
// Rc clone or raw PJRT pointer ever escapes this module, so all
// refcount mutations and C-API calls are serialized (see module docs).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn cpu(artifacts_dir: &str) -> Result<Runtime> {
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Runtime {
            inner: Mutex::new(Inner { client, cache: HashMap::new() }),
            manifest,
            stats: Mutex::new(ExecutionStats::default()),
        })
    }

    fn ensure_loaded(&self, inner: &mut Inner, name: &str) -> Result<()> {
        if inner.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.find(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner.client.compile(&comp)?;
        let dt = t0.elapsed();
        log::info!("compiled artifact '{name}' in {:.1} ms", dt.as_secs_f64() * 1e3);
        {
            let mut s = self.stats.lock().unwrap();
            s.compilations += 1;
            s.total_compile_ns += dt.as_nanos() as u64;
        }
        inner.cache.insert(name.to_string(), Executable { spec, exe });
        Ok(())
    }

    /// Compile an artifact eagerly (idempotent) and return its spec.
    pub fn preload(&self, name: &str) -> Result<ArtifactSpec> {
        let mut inner = self.inner.lock().unwrap();
        self.ensure_loaded(&mut inner, name)?;
        Ok(inner.cache[name].spec.clone())
    }

    /// Execute an artifact by name with host tensors.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let mut inner = self.inner.lock().unwrap();
        self.ensure_loaded(&mut inner, name)?;
        let t0 = Instant::now();
        let out = inner.cache[name].run(inputs)?;
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.total_exec_ns += t0.elapsed().as_nanos() as u64;
        Ok(out)
    }

    pub fn stats(&self) -> ExecutionStats {
        *self.stats.lock().unwrap()
    }
}
