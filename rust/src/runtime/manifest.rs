//! `artifacts/manifest.json` loader.
//!
//! The manifest is written by `python/compile/aot.py` and describes
//! every AOT artifact: file name, kind (grad | loss | update), flat
//! parameter dimension, and the dtype/shape of each input and output
//! tensor. The runtime validates every execution against these specs so
//! a stale artifacts directory fails loudly instead of corrupting a run.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::Result;
use anyhow::{bail, Context};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unsupported dtype '{other}' in manifest"),
        })
    }
}

/// One named tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().context("shape entry not a number"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            dtype: Dtype::parse(j.req_str("dtype")?)?,
            shape,
        })
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// "grad" | "loss" | "update"
    pub kind: String,
    pub model: String,
    pub param_dim: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest plus its base directory.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let version = j.req_usize("version")?;
        if version != 1 {
            bail!("manifest version {version} unsupported (expected 1)");
        }
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            artifacts.push(ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                model: a.req_str("model")?.to_string(),
                param_dim: a.req_usize("param_dim")?,
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
            });
        }
        Ok(ArtifactManifest { dir, artifacts })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| {
                let known: Vec<&str> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
                format!("artifact '{name}' not in manifest (known: {known:?})")
            })
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("r3bft_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"artifacts":[
              {"name":"m1","file":"m1.hlo.txt","kind":"grad","model":"linreg","param_dim":64,
               "inputs":[{"name":"theta","dtype":"f32","shape":[64]},
                         {"name":"x","dtype":"f32","shape":[256,64]},
                         {"name":"y","dtype":"f32","shape":[256]}],
               "outputs":[{"name":"grad","dtype":"f32","shape":[64]},
                          {"name":"loss","dtype":"f32","shape":[1]}]}]}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn loads_and_validates() {
        let dir = sample_manifest_dir();
        let m = ArtifactManifest::load(&dir).unwrap();
        let a = m.find("m1").unwrap();
        assert_eq!(a.param_dim, 64);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].elements(), 256 * 64);
        assert_eq!(a.inputs[1].dtype, Dtype::F32);
        assert!(m.find("nope").is_err());
        assert!(m.hlo_path(a).ends_with("m1.hlo.txt"));
    }

    #[test]
    fn missing_dir_is_friendly() {
        let err = ArtifactManifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
