//! TOML-subset parser for experiment config files (the real toml crate
//! is unavailable offline).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / bool / homogeneous-array values, `#` comments. This covers
//! every config under `configs/` and errors loudly on anything else.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key -> value` flat map ("" section for top-level keys).
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(TomlError {
                    line: ln + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(TomlError {
                line: ln + 1,
                msg: format!("expected key = value, got '{line}'"),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = parse_value(v.trim()).map_err(|msg| TomlError { line: ln + 1, msg })?;
            doc.entries.insert(key, val);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.as_i64())
            .map(|i| i as usize)
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<_>, _> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    s.parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = TomlDoc::parse(
            r#"
# experiment config
name = "e2"            # inline comment
[cluster]
n = 16
f = 2
latency_us = 50.5
[policy]
kind = "bernoulli"
q = 0.25
adaptive = false
qs = [0.1, 0.2, 0.5]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "e2");
        assert_eq!(doc.usize_or("cluster.n", 0), 16);
        assert!((doc.f64_or("cluster.latency_us", 0.0) - 50.5).abs() < 1e-9);
        assert_eq!(doc.str_or("policy.kind", ""), "bernoulli");
        assert!(!doc.bool_or("policy.adaptive", true));
        match doc.get("policy.qs").unwrap() {
            TomlValue::Arr(a) => assert_eq!(a.len(), 3),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = TomlDoc::parse("[unclosed\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.str_or("tag", ""), "a#b");
    }
}
