//! Typed experiment configuration, loadable from a TOML-subset file
//! (`configs/*.toml`) or assembled from CLI flags by `main.rs`.

pub mod toml;

use crate::Result;
use anyhow::{bail, Context};
use toml::TomlDoc;

/// Default assumed per-iteration tamper probability p for policies
/// that model the adversary (the paper's §4.2-§4.3 analysis treats p
/// as a parameter the master postulates). This is the value every
/// non-adaptive policy falls back to, and the default the CLI/config
/// offer for `--p-assumed` / `policy.p_assumed` — kept here as a named
/// constant instead of a literal buried in `FaultCheckPolicy::new`.
pub const DEFAULT_P_ASSUMED: f64 = 0.5;

/// Which fault-check policy the master runs (paper §2, §4).
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyKind {
    /// No auditing at all — the vulnerable vanilla parallelized SGD.
    None,
    /// Deterministic scheme (§4.1): audit every iteration.
    Deterministic,
    /// Randomized scheme (§4.2): audit with fixed probability q.
    Bernoulli { q: f64 },
    /// Adaptive scheme (§4.3): q*_t from Eq. (4) with lambda_t from Eq. (5).
    Adaptive { p_assumed: f64 },
    /// Selective generalization (§5): per-worker probabilities from
    /// reliability scores + outlier boosting on top of a base q.
    Selective { q_base: f64 },
    /// Latency-aware selective auditing: per-worker probabilities from
    /// the fused suspicion score (delivery-latency anomaly + the §5
    /// reliability deficit — see `coordinator::latency`), so slow or
    /// previously-suspect workers are audited first.
    LatencySelective { q_base: f64 },
}

impl PolicyKind {
    pub fn parse(kind: &str, q: f64, p_assumed: f64) -> Result<PolicyKind> {
        Ok(match kind {
            "none" | "vanilla" => PolicyKind::None,
            "deterministic" => PolicyKind::Deterministic,
            "bernoulli" | "randomized" => PolicyKind::Bernoulli { q },
            "adaptive" => PolicyKind::Adaptive { p_assumed },
            "selective" => PolicyKind::Selective { q_base: q },
            "latency-selective" | "latency_selective" => {
                PolicyKind::LatencySelective { q_base: q }
            }
            other => bail!("unknown policy kind '{other}'"),
        })
    }
}

/// Byzantine attack model (DESIGN.md substitution table).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttackKind {
    /// Negate the true gradient and scale it.
    SignFlip,
    /// Add large Gaussian noise.
    Noise,
    /// Send an arbitrary constant vector.
    Constant,
    /// Send zeros (omission-style).
    Zero,
    /// Shift every coordinate by a small epsilon (stealthy).
    SmallBias,
    /// Colluding workers all send the same crafted vector.
    Collude,
}

impl AttackKind {
    pub fn parse(s: &str) -> Result<AttackKind> {
        Ok(match s {
            "sign_flip" | "signflip" => AttackKind::SignFlip,
            "noise" => AttackKind::Noise,
            "constant" => AttackKind::Constant,
            "zero" => AttackKind::Zero,
            "small_bias" | "stealth" => AttackKind::SmallBias,
            "collude" => AttackKind::Collude,
            other => bail!("unknown attack kind '{other}'"),
        })
    }

    pub const ALL: [AttackKind; 6] = [
        AttackKind::SignFlip,
        AttackKind::Noise,
        AttackKind::Constant,
        AttackKind::Zero,
        AttackKind::SmallBias,
        AttackKind::Collude,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::SignFlip => "sign_flip",
            AttackKind::Noise => "noise",
            AttackKind::Constant => "constant",
            AttackKind::Zero => "zero",
            AttackKind::SmallBias => "small_bias",
            AttackKind::Collude => "collude",
        }
    }
}

/// Default warm-up rounds for the `sleeper` adversary strategy.
pub const DEFAULT_SLEEPER_WARMUP: u64 = 10;

/// Default dormancy rounds for the `audit-evader` adversary strategy.
pub const DEFAULT_EVADER_COOLDOWN: u64 = 8;

/// Coordinated adversary strategy (the `crate::adversary` red-team
/// subsystem). When set, the run's Byzantine workers stop flipping
/// stateless per-worker coins and become puppets of one omniscient
/// `AdversaryController` that watches the protocol's public state;
/// `--adversary <strategy>` / `adversary.strategy` select it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdversaryKind {
    /// Tamper a chunk only when colluders own every copy of it, so
    /// replication comparison cannot expose the lie.
    AssignmentAware,
    /// Honest for `warmup` rounds to build trust, then strike.
    Sleeper { warmup: u64 },
    /// Go dormant for `cooldown` rounds after any detection naming a
    /// colluder, then resume.
    AuditEvader { cooldown: u64 },
    /// Lie while shaping response stalls to stay under the EWMA
    /// latency anomaly gates (sim transport).
    LatencyMimic,
    /// Concentrate all lying on the shard whose colluders sit closest
    /// to its 2f_s+1 floor; colluders elsewhere stay honest.
    ShardEquivocator,
}

impl AdversaryKind {
    /// Parse `"name"` or `"name:param"`: `assignment-aware`,
    /// `sleeper[:WARMUP]`, `audit-evader[:COOLDOWN]`, `latency-mimic`,
    /// `shard-equivocator` (underscores accepted).
    pub fn parse(s: &str) -> Result<AdversaryKind> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let num = |default: u64| -> Result<u64> {
            match param {
                None => Ok(default),
                Some(p) => p
                    .parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("bad adversary parameter '{p}' in '{s}'")),
            }
        };
        let kind = match name {
            "assignment-aware" | "assignment_aware" => AdversaryKind::AssignmentAware,
            "sleeper" => AdversaryKind::Sleeper { warmup: num(DEFAULT_SLEEPER_WARMUP)? },
            "audit-evader" | "audit_evader" => {
                AdversaryKind::AuditEvader { cooldown: num(DEFAULT_EVADER_COOLDOWN)? }
            }
            "latency-mimic" | "latency_mimic" => AdversaryKind::LatencyMimic,
            "shard-equivocator" | "shard_equivocator" => AdversaryKind::ShardEquivocator,
            other => bail!(
                "unknown adversary strategy '{other}' (expected assignment-aware | \
                 sleeper[:W] | audit-evader[:C] | latency-mimic | shard-equivocator)"
            ),
        };
        if param.is_some()
            && !matches!(kind, AdversaryKind::Sleeper { .. } | AdversaryKind::AuditEvader { .. })
        {
            bail!("adversary strategy '{name}' takes no parameter (got '{s}')");
        }
        Ok(kind)
    }

    /// Every strategy with its default parameters (experiment sweeps).
    pub const ALL: [AdversaryKind; 5] = [
        AdversaryKind::AssignmentAware,
        AdversaryKind::Sleeper { warmup: DEFAULT_SLEEPER_WARMUP },
        AdversaryKind::AuditEvader { cooldown: DEFAULT_EVADER_COOLDOWN },
        AdversaryKind::LatencyMimic,
        AdversaryKind::ShardEquivocator,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AdversaryKind::AssignmentAware => "assignment-aware",
            AdversaryKind::Sleeper { .. } => "sleeper",
            AdversaryKind::AuditEvader { .. } => "audit-evader",
            AdversaryKind::LatencyMimic => "latency-mimic",
            AdversaryKind::ShardEquivocator => "shard-equivocator",
        }
    }

    /// Name with parameters, parseable by [`AdversaryKind::parse`].
    pub fn describe(&self) -> String {
        match self {
            AdversaryKind::Sleeper { warmup } => format!("sleeper:{warmup}"),
            AdversaryKind::AuditEvader { cooldown } => format!("audit-evader:{cooldown}"),
            other => other.name().to_string(),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct AttackConfig {
    pub kind: AttackKind,
    /// Per-iteration tamper probability p (paper §4.2 analysis).
    pub p: f64,
    /// Attack magnitude multiplier.
    pub magnitude: f32,
}

impl Default for AttackConfig {
    fn default() -> Self {
        AttackConfig {
            kind: AttackKind::SignFlip,
            p: 1.0,
            magnitude: 1.0,
        }
    }
}

/// Worker-transport execution model, parsed once from config/CLI and
/// carried as a proper enum everywhere downstream (the master and the
/// shard builder match on it instead of re-validating strings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// One OS thread per worker over mpsc channels.
    Threaded,
    /// Deterministic virtual-time discrete-event simulation (no OS
    /// threads; scales to thousands of workers).
    Sim,
    /// TCP to standalone worker processes (`r3bft worker --listen`),
    /// one `host:port` peer per worker in `cluster.peers`/`--peers`.
    Net,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s {
            "threaded" => TransportKind::Threaded,
            "sim" => TransportKind::Sim,
            "net" | "tcp" => TransportKind::Net,
            other => bail!("unknown transport '{other}' (expected threaded|sim|net)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Threaded => "threaded",
            TransportKind::Sim => "sim",
            TransportKind::Net => "net",
        }
    }
}

impl From<&str> for TransportKind {
    /// Panicking conversion for literal-heavy test/bench code
    /// (`cluster.transport = "sim".into()`). Config and CLI paths go
    /// through [`TransportKind::parse`], which reports errors instead.
    fn from(s: &str) -> TransportKind {
        TransportKind::parse(s).expect("invalid transport kind literal")
    }
}

/// When the proactive gather may stop waiting for workers. Detection
/// and reactive gathers always wait for every requested copy — only
/// the initial proactive wave is quorum-relaxed (chunks owned solely
/// by abandoned stragglers are reassigned exactly like crashed
/// workers' chunks, so exactness under 2f < n is untouched).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GatherPolicy {
    /// Wait for every scattered-to worker (the paper's synchronous
    /// model; bit-identical to the pre-quorum protocol).
    All,
    /// Proceed once k workers have responded, where k counts
    /// responders at full cluster strength: as crashes/eliminations
    /// shrink the cluster, the allowed-missing margin n - k is what
    /// stays fixed. Must be at least 2f+1 (the identification quorum;
    /// enforced by validate, and floored at runtime with the current
    /// f_t). Sharded runs scale k to each shard's width
    /// (ceil(k * n_s / n)).
    Quorum { k: usize },
    /// Proceed once `us` microseconds have elapsed since the wave was
    /// submitted (virtual time under sim, wall-clock under threaded),
    /// but never with zero responses.
    Deadline { us: u64 },
}

impl GatherPolicy {
    /// Parse "all" | "quorum:K" (absolute) | "quorum:F" with F in
    /// (0, 1] (fraction of n, rounded up) | "deadline:US".
    pub fn parse(s: &str, n: usize) -> Result<GatherPolicy> {
        if s == "all" {
            return Ok(GatherPolicy::All);
        }
        if let Some(v) = s.strip_prefix("quorum:") {
            // "quorum:12" is an absolute count; "quorum:0.8" (any value
            // with a decimal point, in (0, 1]) is a fraction of n
            let k = if v.contains('.') {
                let x: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad quorum fraction '{v}' in gather policy"))?;
                if x <= 0.0 || x > 1.0 {
                    bail!("quorum fraction must be in (0, 1], got '{v}'");
                }
                (x * n as f64).ceil() as usize
            } else {
                v.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad quorum count '{v}' in gather policy"))?
            };
            if k == 0 {
                bail!("quorum must be positive, got '{v}'");
            }
            return Ok(GatherPolicy::Quorum { k });
        }
        if let Some(v) = s.strip_prefix("deadline:") {
            let us: u64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad deadline value '{v}' in gather policy (µs)"))?;
            if us == 0 {
                bail!("deadline must be positive (µs)");
            }
            return Ok(GatherPolicy::Deadline { us });
        }
        bail!("unknown gather policy '{s}' (expected all | quorum:K | quorum:0.F | deadline:US)")
    }

    pub fn describe(&self) -> String {
        match self {
            GatherPolicy::All => "all".into(),
            GatherPolicy::Quorum { k } => format!("quorum:{k}"),
            GatherPolicy::Deadline { us } => format!("deadline:{us}"),
        }
    }
}

/// Cluster topology.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of workers n.
    pub n: usize,
    /// Byzantine tolerance bound f (< n/2).
    pub f: usize,
    /// Ids of the actually-Byzantine workers (|ids| <= f).
    pub byzantine_ids: Vec<usize>,
    /// Simulated per-message latency in microseconds (0 = off).
    pub latency_us: u64,
    /// Execution model. See `coordinator::transport`.
    pub transport: TransportKind,
    /// Proactive gather policy (`cluster.gather` / `--gather`).
    pub gather: GatherPolicy,
    /// Shard count K: 1 = single master; K > 1 partitions the workers
    /// into K contiguous shards, each with its own protocol core,
    /// behind one parameter server. See `coordinator::shard`.
    pub shards: usize,
    /// Round pipeline depth (`cluster.pipeline` / `--pipeline`): 1 =
    /// strictly sequential rounds (the paper's model); D ≥ 2 lets the
    /// master launch iteration t+1's proactive wave on a provisional θ
    /// while iteration t's audit is still in flight, reissuing the
    /// wave only when the audit changed θ. See `coordinator::master`.
    pub pipeline: usize,
    /// Worker addresses (`host:port`) for [`TransportKind::Net`], one
    /// per worker in id order (`cluster.peers` / `--peers a:p,b:p`).
    /// Empty for in-process transports.
    pub peers: Vec<String>,
    /// Net-transport fault-injection spec (`cluster.chaos` /
    /// `--chaos`), in the `coordinator::transport::ChaosSpec` grammar
    /// — e.g. `drop:0.05,delay:20ms,partition:200ms@2s`. `None` (or
    /// `off`) = clean wire. Validation parses the grammar early so a
    /// typo dies at config load, not mid-run.
    pub chaos: Option<String>,
    /// Shared frame-authentication passphrase (`cluster.auth_key` /
    /// `--auth-key`). Both the master and every `r3bft worker` must be
    /// given the same value; `None` = legacy unauthenticated frames.
    pub auth_key: Option<String>,
    pub seed: u64,
}

impl ClusterConfig {
    pub fn new(n: usize, f: usize, seed: u64) -> Self {
        // default: the first f workers are Byzantine (ids are arbitrary
        // from the master's perspective — it never uses them)
        ClusterConfig {
            n,
            f,
            byzantine_ids: (0..f).collect(),
            latency_us: 0,
            transport: TransportKind::Threaded,
            gather: GatherPolicy::All,
            shards: 1,
            pipeline: 1,
            peers: Vec::new(),
            chaos: None,
            auth_key: None,
            seed,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n == 0 {
            bail!("n must be positive");
        }
        match self.gather {
            GatherPolicy::All => {}
            GatherPolicy::Quorum { k } => {
                if k == 0 || k > self.n {
                    bail!("gather quorum k={k} out of range 1..={}", self.n);
                }
                if k < 2 * self.f + 1 {
                    bail!(
                        "gather quorum k={k} below the identification quorum 2f+1={}: \
                         the reactive phase could not assemble a majority vote",
                        2 * self.f + 1
                    );
                }
            }
            GatherPolicy::Deadline { us } => {
                if us == 0 {
                    bail!("gather deadline must be positive (µs)");
                }
            }
        }
        if self.shards == 0 {
            bail!("cluster.shards must be at least 1");
        }
        if self.shards > self.n {
            bail!("cluster.shards = {} exceeds n = {}", self.shards, self.n);
        }
        if self.pipeline == 0 {
            bail!("cluster.pipeline must be at least 1");
        }
        if 2 * self.f >= self.n {
            bail!(
                "f={} violates 2f < n (n={}): the master cannot tolerate n/2 Byzantine workers",
                self.f,
                self.n
            );
        }
        if self.byzantine_ids.len() > self.f {
            bail!(
                "{} Byzantine ids configured but f={}",
                self.byzantine_ids.len(),
                self.f
            );
        }
        if self.byzantine_ids.iter().any(|&b| b >= self.n) {
            bail!("byzantine id out of range");
        }
        match self.transport {
            TransportKind::Net => {
                if self.peers.len() != self.n {
                    bail!(
                        "net transport needs one peer address per worker: \
                         {} peers configured, n = {}",
                        self.peers.len(),
                        self.n
                    );
                }
                if self.peers.iter().any(|p| p.trim().is_empty()) {
                    bail!("empty peer address in cluster.peers");
                }
            }
            _ => {
                if !self.peers.is_empty() {
                    bail!("cluster.peers only applies to the net transport");
                }
                if self.chaos.is_some() {
                    bail!("cluster.chaos only applies to the net transport");
                }
                if self.auth_key.is_some() {
                    bail!("cluster.auth_key only applies to the net transport");
                }
            }
        }
        if let Some(spec) = &self.chaos {
            // fail a bad grammar at config load, not mid-run
            crate::coordinator::transport::ChaosSpec::parse(spec)
                .with_context(|| format!("cluster.chaos '{spec}'"))?;
        }
        if let Some(key) = &self.auth_key {
            if key.trim().is_empty() {
                bail!("cluster.auth_key must not be blank");
            }
        }
        Ok(())
    }
}

/// Model + optimizer for a training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// "linreg" | "mlp" | "transformer"
    pub model: String,
    pub steps: usize,
    pub lr: f32,
    /// Data points per iteration (paper's m).
    pub batch: usize,
    /// Gradient engine: "native" or "xla".
    pub engine: String,
    /// Dataset size N.
    pub dataset_size: usize,
    /// linreg/mlp input dimension.
    pub dim: usize,
    pub noise_std: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "linreg".into(),
            steps: 200,
            lr: 0.1,
            batch: 64,
            engine: "native".into(),
            dataset_size: 4096,
            dim: 64,
            noise_std: 0.0,
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub cluster: ClusterConfig,
    pub policy: PolicyKind,
    pub attack: AttackConfig,
    /// Coordinated adversary strategy for the Byzantine workers
    /// (`adversary.strategy` / `--adversary`). `None` keeps the
    /// stateless per-worker `attack` behaviour; when set, the
    /// `attack.magnitude` knob still scales the coordinated lie and
    /// `attack.kind`/`attack.p` are ignored.
    pub adversary: Option<AdversaryKind>,
    pub train: TrainConfig,
}

impl ExperimentConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let n = doc.usize_or("cluster.n", 8);
        let f = doc.usize_or("cluster.f", 1);
        let seed = doc.usize_or("cluster.seed", 42) as u64;
        let mut cluster = ClusterConfig::new(n, f, seed);
        cluster.latency_us = doc.usize_or("cluster.latency_us", 0) as u64;
        cluster.transport = TransportKind::parse(&doc.str_or("cluster.transport", "threaded"))?;
        cluster.gather = GatherPolicy::parse(&doc.str_or("cluster.gather", "all"), n)?;
        cluster.shards = doc.usize_or("cluster.shards", 1);
        cluster.pipeline = doc.usize_or("cluster.pipeline", 1);
        if let Some(toml::TomlValue::Arr(ids)) = doc.get("cluster.byzantine_ids") {
            cluster.byzantine_ids = ids
                .iter()
                .filter_map(|v| v.as_i64())
                .map(|i| i as usize)
                .collect();
        }
        if let Some(toml::TomlValue::Arr(peers)) = doc.get("cluster.peers") {
            cluster.peers = peers
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(|s| s.to_string())
                        .ok_or_else(|| anyhow::anyhow!("cluster.peers entries must be strings"))
                })
                .collect::<Result<Vec<String>>>()?;
        }
        let chaos = doc.str_or("cluster.chaos", "");
        if !chaos.trim().is_empty() {
            cluster.chaos = Some(chaos);
        }
        let auth_key = doc.str_or("cluster.auth_key", "");
        if !auth_key.is_empty() {
            cluster.auth_key = Some(auth_key);
        }
        cluster.validate()?;

        let policy = PolicyKind::parse(
            &doc.str_or("policy.kind", "bernoulli"),
            doc.f64_or("policy.q", 0.2),
            doc.f64_or("policy.p_assumed", DEFAULT_P_ASSUMED),
        )?;

        let attack = AttackConfig {
            kind: AttackKind::parse(&doc.str_or("attack.kind", "sign_flip"))?,
            p: doc.f64_or("attack.p", 1.0),
            magnitude: doc.f64_or("attack.magnitude", 1.0) as f32,
        };

        // [adversary] strategy = "sleeper", warmup = 20 — the explicit
        // warmup/cooldown keys override the name:param shorthand
        let adversary = match doc.get("adversary.strategy") {
            None => None,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("adversary.strategy must be a string"))?;
                let mut kind = AdversaryKind::parse(s)?;
                if let AdversaryKind::Sleeper { warmup } = &mut kind {
                    *warmup = doc.usize_or("adversary.warmup", *warmup as usize) as u64;
                }
                if let AdversaryKind::AuditEvader { cooldown } = &mut kind {
                    *cooldown = doc.usize_or("adversary.cooldown", *cooldown as usize) as u64;
                }
                // a parameter key for a strategy that does not take it
                // is a misconfigured experiment, not a knob to drop —
                // mirror the CLI's name:param validation
                if doc.get("adversary.warmup").is_some()
                    && !matches!(kind, AdversaryKind::Sleeper { .. })
                {
                    bail!("adversary.warmup only applies to the sleeper strategy");
                }
                if doc.get("adversary.cooldown").is_some()
                    && !matches!(kind, AdversaryKind::AuditEvader { .. })
                {
                    bail!("adversary.cooldown only applies to the audit-evader strategy");
                }
                Some(kind)
            }
        };

        let train = TrainConfig {
            model: doc.str_or("train.model", "linreg"),
            steps: doc.usize_or("train.steps", 200),
            lr: doc.f64_or("train.lr", 0.1) as f32,
            batch: doc.usize_or("train.batch", 64),
            engine: doc.str_or("train.engine", "native"),
            dataset_size: doc.usize_or("train.dataset_size", 4096),
            dim: doc.usize_or("train.dim", 64),
            noise_std: doc.f64_or("train.noise_std", 0.0) as f32,
        };

        Ok(ExperimentConfig {
            name: doc.str_or("name", "unnamed"),
            cluster,
            policy,
            attack,
            adversary,
            train,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_validation() {
        assert!(ClusterConfig::new(3, 1, 0).validate().is_ok());
        assert!(ClusterConfig::new(2, 1, 0).validate().is_err()); // 2f !< n
        assert!(ClusterConfig::new(0, 0, 0).validate().is_err());
        let mut c = ClusterConfig::new(5, 2, 0);
        c.byzantine_ids = vec![0, 1, 2];
        assert!(c.validate().is_err()); // more ids than f
    }

    #[test]
    fn transport_kind_parsed_once() {
        let mut c = ClusterConfig::new(5, 2, 0);
        assert_eq!(c.transport, TransportKind::Threaded);
        c.transport = "sim".into();
        assert_eq!(c.transport, TransportKind::Sim);
        assert!(c.validate().is_ok());
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::Sim.name(), "sim");
        assert_eq!(TransportKind::Threaded.name(), "threaded");
        assert_eq!(TransportKind::parse("net").unwrap(), TransportKind::Net);
        assert_eq!(TransportKind::parse("tcp").unwrap(), TransportKind::Net);
        assert_eq!(TransportKind::Net.name(), "net");
    }

    #[test]
    fn net_transport_requires_matching_peers() {
        let mut c = ClusterConfig::new(3, 1, 0);
        c.transport = TransportKind::Net;
        assert!(c.validate().is_err(), "no peers configured");
        c.peers = vec!["127.0.0.1:9001".into(), "127.0.0.1:9002".into()];
        assert!(c.validate().is_err(), "2 peers for n = 3");
        c.peers.push("127.0.0.1:9003".into());
        assert!(c.validate().is_ok());
        c.peers[1] = "  ".into();
        assert!(c.validate().is_err(), "blank peer address");
        // peers without the net transport is a misconfiguration
        let mut c = ClusterConfig::new(3, 1, 0);
        c.peers = vec!["a:1".into(), "b:2".into(), "c:3".into()];
        assert!(c.validate().is_err());
    }

    #[test]
    fn chaos_and_auth_are_net_only_and_grammar_checked() {
        let mut c = ClusterConfig::new(3, 1, 0);
        c.transport = TransportKind::Net;
        c.peers = vec!["a:1".into(), "b:2".into(), "c:3".into()];
        c.chaos = Some("drop:0.05,delay:20ms".into());
        c.auth_key = Some("correct horse battery staple".into());
        assert!(c.validate().is_ok());
        c.chaos = Some("warp:0.5".into());
        assert!(c.validate().is_err(), "bad chaos grammar must die at config load");
        c.chaos = Some("off".into());
        assert!(c.validate().is_ok(), "'off' is the documented no-op spec");
        c.auth_key = Some("  ".into());
        assert!(c.validate().is_err(), "blank auth key");
        // either knob without the net transport is a misconfiguration
        let mut c = ClusterConfig::new(3, 1, 0);
        c.chaos = Some("drop:0.1".into());
        assert!(c.validate().is_err());
        let mut c = ClusterConfig::new(3, 1, 0);
        c.auth_key = Some("k".into());
        assert!(c.validate().is_err());
    }

    #[test]
    fn chaos_and_auth_from_doc() {
        let doc = TomlDoc::parse(
            "[cluster]\nn = 2\nf = 0\ntransport = \"net\"\n\
             peers = [\"127.0.0.1:9001\", \"127.0.0.1:9002\"]\n\
             chaos = \"drop:0.05,delay:20ms\"\nauth_key = \"swordfish\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.chaos.as_deref(), Some("drop:0.05,delay:20ms"));
        assert_eq!(cfg.cluster.auth_key.as_deref(), Some("swordfish"));
    }

    #[test]
    fn net_peers_from_doc() {
        let doc = TomlDoc::parse(
            "[cluster]\nn = 2\nf = 0\ntransport = \"net\"\n\
             peers = [\"127.0.0.1:9001\", \"127.0.0.1:9002\"]\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.transport, TransportKind::Net);
        assert_eq!(cfg.cluster.peers, vec!["127.0.0.1:9001", "127.0.0.1:9002"]);
    }

    #[test]
    fn transport_from_doc() {
        let doc = TomlDoc::parse("[cluster]\nn = 5\nf = 1\ntransport = \"sim\"\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.transport, TransportKind::Sim);
        assert_eq!(cfg.cluster.shards, 1);
        assert!(TomlDoc::parse("[cluster]\nn = 5\nf = 1\ntransport = \"bogus\"\n")
            .ok()
            .and_then(|d| ExperimentConfig::from_doc(&d).ok())
            .is_none());
    }

    #[test]
    fn gather_policy_parse_and_validate() {
        assert_eq!(GatherPolicy::parse("all", 10).unwrap(), GatherPolicy::All);
        // absolute count
        assert_eq!(GatherPolicy::parse("quorum:7", 10).unwrap(), GatherPolicy::Quorum { k: 7 });
        // fraction of n, rounded up: ceil(0.8 * 10) = 8
        assert_eq!(GatherPolicy::parse("quorum:0.8", 10).unwrap(), GatherPolicy::Quorum { k: 8 });
        // quorum:1.0 is the full cluster (fraction), quorum:1 is k = 1
        assert_eq!(GatherPolicy::parse("quorum:1.0", 10).unwrap(), GatherPolicy::Quorum { k: 10 });
        assert_eq!(GatherPolicy::parse("quorum:1", 10).unwrap(), GatherPolicy::Quorum { k: 1 });
        assert_eq!(
            GatherPolicy::parse("deadline:500", 10).unwrap(),
            GatherPolicy::Deadline { us: 500 }
        );
        assert!(GatherPolicy::parse("quorum:0", 10).is_err());
        assert!(GatherPolicy::parse("deadline:0", 10).is_err());
        assert!(GatherPolicy::parse("bogus", 10).is_err());

        let mut c = ClusterConfig::new(8, 2, 0);
        c.gather = GatherPolicy::Quorum { k: 9 }; // k > n
        assert!(c.validate().is_err());
        c.gather = GatherPolicy::Quorum { k: 8 };
        assert!(c.validate().is_ok());

        // config file path
        let doc =
            TomlDoc::parse("[cluster]\nn = 16\nf = 2\ngather = \"quorum:0.75\"\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.gather, GatherPolicy::Quorum { k: 12 });
        assert_eq!(cfg.cluster.gather.describe(), "quorum:12");
    }

    #[test]
    fn shards_validated_and_parsed() {
        let mut c = ClusterConfig::new(8, 2, 0);
        assert_eq!(c.shards, 1);
        c.shards = 4;
        assert!(c.validate().is_ok());
        c.shards = 0;
        assert!(c.validate().is_err());
        c.shards = 9; // more shards than workers
        assert!(c.validate().is_err());

        let doc =
            TomlDoc::parse("[cluster]\nn = 16\nf = 2\ntransport = \"sim\"\nshards = 4\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.shards, 4);
    }

    #[test]
    fn pipeline_validated_and_parsed() {
        let mut c = ClusterConfig::new(8, 2, 0);
        assert_eq!(c.pipeline, 1);
        c.pipeline = 3;
        assert!(c.validate().is_ok());
        c.pipeline = 0;
        assert!(c.validate().is_err());

        let doc =
            TomlDoc::parse("[cluster]\nn = 8\nf = 1\ntransport = \"sim\"\npipeline = 2\n").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.pipeline, 2);
        // default is strictly sequential
        let doc = TomlDoc::parse("[cluster]\nn = 8\nf = 1\n").unwrap();
        assert_eq!(ExperimentConfig::from_doc(&doc).unwrap().cluster.pipeline, 1);
    }

    #[test]
    fn parse_policy_kinds() {
        assert_eq!(
            PolicyKind::parse("bernoulli", 0.3, 0.0).unwrap(),
            PolicyKind::Bernoulli { q: 0.3 }
        );
        assert_eq!(
            PolicyKind::parse("deterministic", 0.0, 0.0).unwrap(),
            PolicyKind::Deterministic
        );
        assert_eq!(
            PolicyKind::parse("latency-selective", 0.25, 0.0).unwrap(),
            PolicyKind::LatencySelective { q_base: 0.25 }
        );
        assert_eq!(
            PolicyKind::parse("latency_selective", 0.25, 0.0).unwrap(),
            PolicyKind::LatencySelective { q_base: 0.25 }
        );
        assert!(PolicyKind::parse("bogus", 0.0, 0.0).is_err());
    }

    #[test]
    fn adversary_kind_parse() {
        assert_eq!(
            AdversaryKind::parse("assignment-aware").unwrap(),
            AdversaryKind::AssignmentAware
        );
        assert_eq!(
            AdversaryKind::parse("sleeper").unwrap(),
            AdversaryKind::Sleeper { warmup: DEFAULT_SLEEPER_WARMUP }
        );
        assert_eq!(
            AdversaryKind::parse("sleeper:25").unwrap(),
            AdversaryKind::Sleeper { warmup: 25 }
        );
        assert_eq!(
            AdversaryKind::parse("audit_evader:4").unwrap(),
            AdversaryKind::AuditEvader { cooldown: 4 }
        );
        assert_eq!(AdversaryKind::parse("latency-mimic").unwrap(), AdversaryKind::LatencyMimic);
        assert_eq!(
            AdversaryKind::parse("shard-equivocator").unwrap(),
            AdversaryKind::ShardEquivocator
        );
        assert!(AdversaryKind::parse("bogus").is_err());
        assert!(AdversaryKind::parse("sleeper:x").is_err());
        assert!(AdversaryKind::parse("latency-mimic:3").is_err(), "no parameter accepted");
        // describe() round-trips through parse()
        for kind in AdversaryKind::ALL {
            assert_eq!(AdversaryKind::parse(&kind.describe()).unwrap(), kind);
        }
    }

    #[test]
    fn adversary_from_doc() {
        let doc = TomlDoc::parse(
            "[cluster]\nn = 8\nf = 2\n[adversary]\nstrategy = \"sleeper\"\nwarmup = 30\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.adversary, Some(AdversaryKind::Sleeper { warmup: 30 }));
        // no [adversary] section: stateless attacks stay in charge
        let doc = TomlDoc::parse("[cluster]\nn = 8\nf = 2\n").unwrap();
        assert_eq!(ExperimentConfig::from_doc(&doc).unwrap().adversary, None);
        // a parameter key for a strategy that does not take it is an
        // error, not a silently-dropped knob (mirrors the CLI)
        let doc = TomlDoc::parse(
            "[cluster]\nn = 8\nf = 2\n[adversary]\nstrategy = \"latency-mimic\"\nwarmup = 20\n",
        )
        .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        let doc = TomlDoc::parse(
            "[cluster]\nn = 8\nf = 2\n[adversary]\nstrategy = \"sleeper\"\ncooldown = 4\n",
        )
        .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn full_config_from_doc() {
        let doc = TomlDoc::parse(
            r#"
name = "test"
[cluster]
n = 9
f = 2
byzantine_ids = [3, 7]
[policy]
kind = "adaptive"
p_assumed = 0.4
[attack]
kind = "noise"
p = 0.5
magnitude = 10.0
[train]
model = "mlp"
steps = 50
"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.n, 9);
        assert_eq!(cfg.cluster.byzantine_ids, vec![3, 7]);
        assert_eq!(cfg.policy, PolicyKind::Adaptive { p_assumed: 0.4 });
        assert_eq!(cfg.attack.kind, AttackKind::Noise);
        assert_eq!(cfg.train.model, "mlp");
        assert_eq!(cfg.train.steps, 50);
    }
}
