//! Net transport integration tests: a loopback TCP run must be
//! *bit-identical* to the threaded and sim runs for the same seed and
//! config (flat and sharded), and a SIGKILLed worker process must
//! surface as an in-band crash-stop — chunks reassigned, no faulty
//! update, never a hang.

use std::io::BufRead;
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::thread::JoinHandle;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::transport::net::server;
use r3bft::coordinator::TrainOutcome;
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};
use r3bft::linalg;
use r3bft::trace::Recorder;

/// Host `n` workers on in-process threads (the compute core is
/// identical to the standalone `r3bft worker` binary's); returns their
/// addresses in worker-id order.
fn spawn_worker_threads(n: usize) -> (Vec<String>, Vec<JoinHandle<()>>) {
    let mut peers = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        peers.push(listener.local_addr().expect("local addr").to_string());
        handles.push(std::thread::spawn(move || {
            server::serve(listener).expect("worker serve");
        }));
    }
    (peers, handles)
}

#[allow(clippy::too_many_arguments)]
fn run(
    n: usize,
    f: usize,
    shards: usize,
    byz: Vec<usize>,
    policy: PolicyKind,
    attack: AttackConfig,
    steps: usize,
    seed: u64,
    transport: &str,
    compress: Option<&str>,
    peers: Vec<String>,
    recorder: Option<Arc<Recorder>>,
) -> (TrainOutcome, Vec<f32>) {
    let mut cluster = ClusterConfig::new(n, f, seed);
    cluster.byzantine_ids = byz;
    cluster.transport = transport.into();
    cluster.shards = shards;
    cluster.peers = peers;
    let cfg = ExperimentConfig {
        name: format!("net-test-{transport}-{shards}"),
        cluster,
        policy,
        attack,
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    };
    let d = 16usize;
    let chunk = 8usize;
    let ds = Arc::new(LinRegDataset::generate(2048, d, 0.0, seed));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(seed);
    let compressor = compress.map(|s| r3bft::coordinator::compress::parse(s).expect("compressor"));
    let opts = MasterOptions {
        w_star: Some(w_star.clone()),
        compressor,
        net_model: Some(spec.clone()),
        recorder,
        ..Default::default()
    };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    (master.run().expect("train"), w_star)
}

/// Acceptance: net-at-loopback ≡ threaded ≡ sim under
/// `GatherPolicy::All`, fixed seed — identical eliminations, bitwise
/// identical theta, identical efficiency accounting. Dense and
/// sign-compressed wires.
#[test]
fn net_threaded_and_sim_are_bit_identical_flat() {
    let scenarios: Vec<(PolicyKind, AttackConfig, Vec<usize>, Option<&str>)> = vec![
        (
            PolicyKind::Bernoulli { q: 0.3 },
            AttackConfig { kind: AttackKind::SignFlip, p: 0.6, magnitude: 2.0 },
            vec![2, 5],
            None,
        ),
        (
            PolicyKind::Deterministic,
            AttackConfig { kind: AttackKind::Noise, p: 1.0, magnitude: 3.0 },
            vec![1, 4],
            Some("sign"),
        ),
    ];
    for (policy, attack, byz, compress) in scenarios {
        let label = format!("{policy:?}/{:?}/{compress:?}", attack.kind);
        let n = 9;
        let (peers, workers) = spawn_worker_threads(n);
        let (net, _) = run(
            n,
            2,
            1,
            byz.clone(),
            policy.clone(),
            attack.clone(),
            80,
            7,
            "net",
            compress,
            peers,
            None,
        );
        let (threaded, _) = run(
            n,
            2,
            1,
            byz.clone(),
            policy.clone(),
            attack.clone(),
            80,
            7,
            "threaded",
            compress,
            vec![],
            None,
        );
        let (sim, _) =
            run(n, 2, 1, byz, policy, attack, 80, 7, "sim", compress, vec![], None);
        assert_eq!(net.eliminated, threaded.eliminated, "{label}: eliminated diverged");
        assert_eq!(net.theta, threaded.theta, "{label}: theta diverged (not bit-identical)");
        assert_eq!(net.theta, sim.theta, "{label}: net vs sim theta diverged");
        assert_eq!(
            net.metrics.average_efficiency(),
            threaded.metrics.average_efficiency(),
            "{label}: efficiency accounting diverged"
        );
        assert_eq!(net.events.detections(), threaded.events.detections(), "{label}");
        // the master said Shutdown on drop; every worker thread exits
        for h in workers {
            h.join().expect("worker thread");
        }
        // honest wire accounting: the TCP figure includes the theta
        // broadcast and frame headers, so it strictly dominates the
        // payload-only figure the in-process transports report
        let net_bytes: u64 = net.metrics.iterations.iter().map(|r| r.bytes_round).sum();
        let thr_bytes: u64 = threaded.metrics.iterations.iter().map(|r| r.bytes_round).sum();
        assert!(net_bytes > thr_bytes, "{label}: net bytes {net_bytes} <= payload {thr_bytes}");
        // loopback sessions never drop
        assert!(net.metrics.iterations.iter().all(|r| r.net_reconnects == 0), "{label}");
    }
}

/// Acceptance: the sharded net fleet (each shard's inner transport a
/// slice of the peer list) matches sharded threaded bitwise.
#[test]
fn net_matches_threaded_bitwise_sharded() {
    let n = 12;
    let byz = vec![1usize, 4, 7, 10]; // one liar per shard
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 3.0 };
    let (peers, workers) = spawn_worker_threads(n);
    let (net, w_star) = run(
        n,
        4,
        4,
        byz.clone(),
        PolicyKind::Deterministic,
        attack.clone(),
        60,
        11,
        "net",
        None,
        peers,
        None,
    );
    let (threaded, _) = run(
        n,
        4,
        4,
        byz.clone(),
        PolicyKind::Deterministic,
        attack,
        60,
        11,
        "threaded",
        None,
        vec![],
        None,
    );
    assert_eq!(net.eliminated, threaded.eliminated, "sharded eliminated diverged");
    assert_eq!(net.theta, threaded.theta, "sharded theta diverged (not bit-identical)");
    let mut elim = net.eliminated.clone();
    elim.sort_unstable();
    assert_eq!(elim, byz, "every liar identified");
    let dist = linalg::dist2(&net.theta, &w_star);
    assert!(dist < 1e-2, "sharded net run failed to converge: dist={dist}");
    for h in workers {
        h.join().expect("worker thread");
    }
}

/// Launch one real `r3bft worker` process and parse the bound address
/// it announces.
fn spawn_worker_process() -> (String, Child) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_r3bft"))
        .args(["worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn r3bft worker");
    let stdout = child.stdout.take().expect("worker stdout");
    let mut line = String::new();
    std::io::BufReader::new(stdout).read_line(&mut line).expect("read listen line");
    let addr = line
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
        .to_string();
    (addr, child)
}

/// Acceptance: SIGKILLing a worker *process* mid-run surfaces as an
/// in-band crash-stop — the master reassigns its chunks and finishes
/// every iteration; the kill is never an identification and never a
/// faulty update.
#[test]
fn killed_worker_process_becomes_in_band_crash_stop() {
    let n = 5;
    let victim = 3usize;
    let mut peers = Vec::with_capacity(n);
    let mut children = Vec::with_capacity(n);
    for _ in 0..n {
        let (addr, child) = spawn_worker_process();
        peers.push(addr);
        children.push(child);
    }
    // hard-kill the victim once the run is warmed up; worker-side
    // latency keeps the run long enough that the kill lands mid-run
    let killer = {
        let mut victim_child = children.remove(victim);
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(250));
            let _ = victim_child.kill();
            let _ = victim_child.wait();
        })
    };
    let mut cluster = ClusterConfig::new(n, 1, 13);
    cluster.transport = "net".into();
    cluster.peers = peers;
    cluster.latency_us = 1500;
    let steps = 300usize;
    let cfg = ExperimentConfig {
        name: "net-kill".into(),
        cluster,
        policy: PolicyKind::None,
        attack: AttackConfig::default(),
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    };
    let d = 16usize;
    let chunk = 8usize;
    let ds = Arc::new(LinRegDataset::generate(2048, d, 0.0, 13));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(13);
    let opts = MasterOptions {
        w_star: Some(w_star.clone()),
        net_model: Some(spec.clone()),
        ..Default::default()
    };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    let out = master.run().expect("train must survive the kill");
    killer.join().expect("killer thread");
    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }

    // the kill is a crash-stop, not an identification or a hang
    assert_eq!(out.crashed, vec![victim], "victim must crash-stop in-band");
    assert!(out.eliminated.is_empty(), "a kill is not an identification");
    assert_eq!(out.events.crashes(), 1);
    assert_eq!(out.metrics.iterations.len(), steps, "run must finish every iteration");
    // orphaned chunks were reassigned: the crash round and every later
    // round still used one gradient per chunk, and the run converged
    assert!(out.theta.iter().all(|v| v.is_finite()));
    assert_eq!(out.metrics.faulty_update_rate(), 0.0, "no faulty update from a crash");
    let crash_iter = out
        .metrics
        .iterations
        .iter()
        .position(|r| r.crashed > 0)
        .expect("some iteration records the crash");
    let rec = &out.metrics.iterations[crash_iter];
    assert_eq!(rec.gradients_used, rec.gradients_computed, "accounting stays exact");
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(dist < 1e-2, "crash scenario failed to converge: dist={dist}");
}

/// Tentpole acceptance: attaching a recorder to a net run switches the
/// worker-side telemetry on (spans, clock sync, Telemetry frames) — and
/// the protocol must not notice. θ, the elimination set, and the
/// detection count stay bit-identical to the telemetry-off run, while
/// the recorder fills with clock-aligned worker spans, per-link health
/// snapshots, worker-labeled metric families, and worker-process rows
/// in the Chrome export.
#[test]
fn net_telemetry_is_protocol_neutral_and_observable() {
    let n = 6;
    let byz = vec![1usize, 4];
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 2.0 };
    let steps = 60;
    let seed = 5;

    // telemetry off: the baseline wire (no recorder ⇒ hello asks for
    // nothing, the worker ships nothing)
    let (peers, workers) = spawn_worker_threads(n);
    let (off, _) = run(
        n,
        1,
        1,
        byz.clone(),
        PolicyKind::Deterministic,
        attack.clone(),
        steps,
        seed,
        "net",
        None,
        peers,
        None,
    );
    for h in workers {
        h.join().expect("worker thread");
    }

    // telemetry on: same seed, recorder attached
    let rec = Recorder::new();
    let (peers, workers) = spawn_worker_threads(n);
    let (on, _) = run(
        n,
        1,
        1,
        byz.clone(),
        PolicyKind::Deterministic,
        attack,
        steps,
        seed,
        "net",
        None,
        peers,
        Some(rec.clone()),
    );
    for h in workers {
        h.join().expect("worker thread");
    }

    // protocol neutrality: bit-identical outcome
    assert_eq!(on.theta, off.theta, "telemetry must not perturb theta (bit-identical)");
    assert_eq!(on.eliminated, off.eliminated, "telemetry must not perturb eliminations");
    assert_eq!(
        on.events.detections(),
        off.events.detections(),
        "telemetry must not perturb detections"
    );

    // ...and the telemetry actually arrived: worker spans on the master
    // clock, every kind represented, sane intervals
    let spans = rec.worker_spans();
    assert!(!spans.is_empty(), "a telemetry-enabled run must ship worker spans");
    for kind in [0u8, 1, 2] {
        assert!(
            spans.iter().any(|s| s.kind == kind),
            "span kind {kind} (compute/decode/encode) missing"
        );
    }
    assert!(spans.iter().all(|s| s.start_ns <= s.end_ns), "spans must be well-formed");
    assert!(spans.iter().all(|s| s.worker < n), "span worker ids must be in roster");

    // per-link health snapshots for every worker, with real traffic
    let links = rec.links();
    assert_eq!(links.len(), n, "every link must report a health snapshot");
    assert!(
        links.values().all(|l| l.requests > 0),
        "every worker served requests over the run"
    );
    assert!(
        links.values().all(|l| l.auth_rejects == 0 && l.reconnects == 0),
        "clean loopback run: no rejects, no reconnects"
    );

    // the live scrape carries the worker-labeled families
    let prom = rec.prometheus_live();
    for family in [
        "r3bft_net_resends_total",
        "r3bft_auth_rejects_total",
        "r3bft_net_dup_requests_total",
        "r3bft_net_chaos_hits_total",
        "r3bft_net_link_rtt_ns",
        "r3bft_net_link_clock_offset_ns",
        "r3bft_worker_span_queue_depth",
        "r3bft_worker_dropped_spans_total",
    ] {
        assert!(prom.contains(family), "live scrape missing family {family}");
    }
    assert!(
        prom.contains("r3bft_net_link_rtt_ns{worker=\"0\"}"),
        "labeled series must carry worker labels"
    );
    // the deterministic snapshot stays label-free (unchanged by the run)
    assert!(!rec.prometheus().contains("worker=\""), "--metrics-out snapshot must stay fixed");

    // the Chrome export grows dedicated worker-process rows whose
    // compute spans also nest into the master's delivery lanes
    let trace = rec.chrome_trace();
    assert!(trace.contains("worker 0 (remote)"), "worker-process row metadata missing");
    assert!(trace.contains("\"worker_compute\""), "nested compute slices missing");
}
