//! Completion-driven gather tests: `Quorum { k: n }` must be
//! bit-identical to `All` on both transports, a quorum gather must cut
//! straggler-dominated virtual round time to quorum-dominated, late
//! deliveries from an abandoned wave must be drained (never ingested),
//! deadlines must cap the wait, and sharded runs must apply the quorum
//! per shard.

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, GatherPolicy, PolicyKind,
    TrainConfig,
};
use r3bft::coordinator::byzantine::ByzantineBehavior;
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::protocol::{ProtocolConfig, ProtocolCore};
use r3bft::coordinator::{
    EventLog, FaultCheckPolicy, LatencyModel, SimConfig, SimTransport, TrainOutcome,
};
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};

#[allow(clippy::too_many_arguments)]
fn run(
    n: usize,
    f: usize,
    byz: Vec<usize>,
    policy: PolicyKind,
    attack: AttackConfig,
    steps: usize,
    seed: u64,
    transport: &str,
    shards: usize,
    gather: GatherPolicy,
    sim: SimConfig,
) -> TrainOutcome {
    let mut cluster = ClusterConfig::new(n, f, seed);
    cluster.byzantine_ids = byz;
    cluster.transport = transport.into();
    cluster.gather = gather;
    cluster.shards = shards;
    let cfg = ExperimentConfig {
        name: "gather-test".into(),
        cluster,
        policy,
        attack,
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    };
    let d = 16usize;
    let chunk = 8usize;
    let ds = Arc::new(LinRegDataset::generate(2048, d, 0.0, seed));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(seed);
    let opts = MasterOptions { sim, ..Default::default() };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    master.run().expect("train")
}

fn mean_round_us(out: &TrainOutcome) -> f64 {
    out.metrics.mean_round_ns() / 1e3
}

/// Property: a quorum of the whole cluster never stops early, so
/// `Quorum { k: n }` is bit-identical to `All` — on both transports,
/// with liars, audits, and (for sim) nonzero latency + a straggler.
#[test]
fn quorum_of_n_is_bit_identical_to_all_on_both_transports() {
    let byz = vec![2usize, 5];
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 0.6, magnitude: 2.0 };
    let scenarios: Vec<(&str, SimConfig)> = vec![
        ("threaded", SimConfig::default()),
        ("sim", SimConfig::default()),
        (
            "sim",
            SimConfig {
                latency: LatencyModel::Fixed { us: 100 },
                stragglers: vec![(7, 10.0)],
                ..Default::default()
            },
        ),
    ];
    for (transport, sim) in scenarios {
        let all = run(
            9,
            2,
            byz.clone(),
            PolicyKind::Bernoulli { q: 0.3 },
            attack.clone(),
            100,
            7,
            transport,
            1,
            GatherPolicy::All,
            sim.clone(),
        );
        let quorum = run(
            9,
            2,
            byz.clone(),
            PolicyKind::Bernoulli { q: 0.3 },
            attack.clone(),
            100,
            7,
            transport,
            1,
            GatherPolicy::Quorum { k: 9 },
            sim,
        );
        let label = format!("{transport}: Quorum{{k=n}} vs All");
        assert_eq!(all.theta, quorum.theta, "{label}: theta diverged");
        assert_eq!(all.eliminated, quorum.eliminated, "{label}: eliminated diverged");
        assert_eq!(all.events.audits(), quorum.events.audits(), "{label}");
        assert_eq!(all.events.detections(), quorum.events.detections(), "{label}");
        assert_eq!(quorum.events.stragglers(), 0, "{label}: k=n abandoned someone");
    }
}

/// At zero latency every delivery of a wave shares one arrival
/// instant, so even a partial quorum ingests the full wave on the
/// deterministic simulator — quorum only bites when stragglers exist.
#[test]
fn partial_quorum_at_zero_latency_is_bit_identical_to_all_on_sim() {
    let byz = vec![1usize, 4];
    let attack = AttackConfig { kind: AttackKind::Noise, p: 1.0, magnitude: 3.0 };
    let all = run(
        9,
        2,
        byz.clone(),
        PolicyKind::Deterministic,
        attack.clone(),
        80,
        11,
        "sim",
        1,
        GatherPolicy::All,
        SimConfig::default(),
    );
    let quorum = run(
        9,
        2,
        byz,
        PolicyKind::Deterministic,
        attack,
        80,
        11,
        "sim",
        1,
        GatherPolicy::Quorum { k: 5 },
        SimConfig::default(),
    );
    assert_eq!(all.theta, quorum.theta, "zero-latency partial quorum diverged");
    assert_eq!(all.eliminated, quorum.eliminated);
    assert_eq!(quorum.events.stragglers(), 0);
}

/// The headline scenario: one 50x straggler. Under `All` every round
/// waits ~5000us of virtual time for it; under `Quorum { n-1 }` the
/// round proceeds at ~100us plus one ~100us reassignment wave.
#[test]
fn quorum_cuts_straggler_round_time() {
    let n = 16usize;
    let steps = 10usize;
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(n - 1, 50.0)],
        ..Default::default()
    };
    let all = run(
        n,
        0,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        steps,
        13,
        "sim",
        1,
        GatherPolicy::All,
        sim.clone(),
    );
    let quorum = run(
        n,
        0,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        steps,
        13,
        "sim",
        1,
        GatherPolicy::Quorum { k: n - 1 },
        sim,
    );
    let all_us = mean_round_us(&all);
    let quorum_us = mean_round_us(&quorum);
    // All is straggler-dominated: 100us * 50
    assert!(
        (all_us - 5000.0).abs() < 1.0,
        "All round should be straggler-dominated: {all_us}us"
    );
    // Quorum is quorum-dominated: base wave + reassignment wave
    assert!(
        quorum_us <= 500.0,
        "Quorum round should be quorum-dominated: {quorum_us}us"
    );
    assert!(
        all_us >= 2.0 * quorum_us,
        "quorum speedup below 2x: all={all_us}us quorum={quorum_us}us"
    );
    // the straggler was abandoned every round but never crashed or
    // eliminated, and the update still used every sampled gradient
    assert_eq!(quorum.events.stragglers(), steps);
    assert!(quorum.crashed.is_empty());
    assert!(quorum.eliminated.is_empty());
    for rec in &quorum.metrics.iterations {
        assert_eq!(rec.stragglers, 1);
        assert_eq!(rec.gradients_used, (n * 8) as u64, "m must be unchanged");
        assert!(rec.round_ns > 0);
    }
}

/// Cross-phase drain: the straggler here is Byzantine AND abandoned by
/// the proactive quorum; its late (tampered) proactive delivery
/// arrives while the detection wave is in flight and must be drained,
/// not ingested — so detection sees only honest copies and flags
/// nothing.
#[test]
fn late_proactive_delivery_is_drained_not_ingested() {
    let n = 4usize;
    let seed = 21u64;
    let cs = 4usize;
    let d = 8usize;
    let ds = LinRegDataset::generate(256, d, 0.0, seed);
    let engine: Arc<dyn GradientComputer> =
        Arc::new(NativeEngine::new(ModelSpec::LinReg { d, batch: cs }));
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 3.0 };
    // worker 3: Byzantine and a 1.5x straggler, so its proactive
    // delivery (150us) lands mid-detection (detection wave: 100->200us)
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(3, 1.5)],
        ..Default::default()
    };
    let transport = SimTransport::new(
        n,
        engine.clone(),
        |w| (w == 3).then(|| ByzantineBehavior::new(attack.clone(), seed, w)),
        None,
        sim,
    );
    let policy = FaultCheckPolicy::new(PolicyKind::Deterministic, n, seed);
    let mut core = ProtocolCore::new(
        Box::new(transport),
        policy,
        ProtocolConfig {
            f: 1,
            seed,
            chunk_size: cs,
            self_check: false,
            tol: 0.0,
            no_eliminate: false,
            compressor: None,
            gather: GatherPolicy::Quorum { k: 3 },
            pipeline: 1,
        },
    );
    let theta = Arc::new(vec![0.1f32; d]);
    let mut events = EventLog::default();
    let out = core
        .run_round(0, &theta, &ds, engine.as_ref(), &mut events)
        .expect("round");
    // the straggler was abandoned...
    assert_eq!(out.stragglers_now, vec![3]);
    assert_eq!(events.stragglers(), 1);
    // ...and despite its tampered symbols arriving mid-detection, no
    // copy of worker 3 exists anywhere in the round
    let round = core.round();
    for c in 0..round.nchunks() {
        assert!(
            round.chunks[c].copies.iter().all(|s| s.worker != 3),
            "chunk {c} ingested a drained symbol"
        );
        // deterministic policy: every audited chunk reached f_t+1 copies
        assert!(round.chunks[c].copies.len() >= 2, "chunk {c} under-replicated");
    }
    // only honest copies were compared: no fault, no elimination
    assert_eq!(out.faults_detected, 0, "a drained tampered symbol was compared");
    assert!(out.identified_now.is_empty());
    assert!(out.crashed_now.is_empty(), "a straggle is not a crash");
    // wave timeline: proactive 100us + detection top-up 100us
    assert_eq!(out.round_ns, 200_000);
}

/// Deadline gather: the wave ends at the deadline (never
/// empty-handed), stragglers' chunks are reassigned, training goes on.
#[test]
fn deadline_gather_proceeds_at_the_deadline() {
    let n = 8usize;
    let steps = 5usize;
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(n - 1, 50.0)],
        ..Default::default()
    };
    let out = run(
        n,
        0,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        steps,
        23,
        "sim",
        1,
        GatherPolicy::Deadline { us: 300 },
        sim,
    );
    let us = mean_round_us(&out);
    assert!(
        (300.0..1000.0).contains(&us),
        "deadline round should cost ~deadline + one reassignment wave, got {us}us"
    );
    assert_eq!(out.events.stragglers(), steps);
    assert!(out.crashed.is_empty());
}

/// Abandonment-streak feedback (the PR-4 ROADMAP follow-up): a worker
/// abandoned in ABANDON_STREAK consecutive rounds is chronic, and the
/// quorum stops budgeting a response slot for it. With two stragglers
/// and `allowed missing = 1`, the first rounds are gated by the
/// *faster* straggler (the slot the slower one would have used); once
/// the slower straggler turns chronic the effective quorum shrinks and
/// the rounds drop to base latency.
#[test]
fn chronic_straggler_shrinks_the_effective_quorum() {
    use r3bft::coordinator::protocol::ABANDON_STREAK;
    let n = 8usize;
    let steps = 8usize;
    // worker 6: 30x (3000us), worker 7: 50x (5000us)
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(6, 30.0), (7, 50.0)],
        ..Default::default()
    };
    let out = run(
        n,
        0,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        steps,
        31,
        "sim",
        1,
        GatherPolicy::Quorum { k: n - 1 },
        sim,
    );
    let streak = ABANDON_STREAK as usize;
    for (i, rec) in out.metrics.iterations.iter().enumerate() {
        let us = rec.round_ns as f64 / 1e3;
        if i < streak {
            // worker 7's slot is filled by worker 6's 3000us response
            // (plus, at worst, a reassignment wave that lands on the
            // 30x straggler again)
            assert!(
                us >= 3000.0,
                "round {i} should be gated by the 30x straggler, got {us}us"
            );
            assert_eq!(rec.stragglers, 1, "round {i}: only worker 7 abandoned");
        } else {
            // worker 7 is chronic: the quorum shrinks, both stragglers
            // are abandoned, and the round runs at base + reassignment
            assert!(
                us <= 500.0,
                "round {i} should be quorum-dominated after the shrink, got {us}us"
            );
            assert_eq!(rec.stragglers, 2, "round {i}: both stragglers abandoned");
        }
    }
    // a straggle is never a crash or an elimination
    assert!(out.crashed.is_empty() && out.eliminated.is_empty());
}

/// The shrink never cuts below the 2f_t+1 identification floor: with
/// f = 2 (floor 5) and three chronic stragglers on an n = 8 cluster,
/// every wave keeps at least 5 responders no matter how many workers
/// turn chronic.
#[test]
fn quorum_shrink_preserves_the_identification_floor() {
    let n = 8usize;
    let f = 2usize;
    let steps = 12usize;
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(5, 30.0), (6, 40.0), (7, 50.0)],
        ..Default::default()
    };
    let out = run(
        n,
        f,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        steps,
        37,
        "sim",
        1,
        GatherPolicy::Quorum { k: 6 },
        sim,
    );
    for (i, rec) in out.metrics.iterations.iter().enumerate() {
        // responders = n - abandoned must never drop below 2f+1 = 5
        assert!(
            n - rec.stragglers >= 2 * f + 1,
            "round {i} kept only {} responders (floor {})",
            n - rec.stragglers,
            2 * f + 1
        );
        assert_eq!(rec.gradients_used, (n * 8) as u64, "m must be unchanged");
    }
    // by the tail every straggler is chronic and the floor binds
    let last = out.metrics.iterations.last().unwrap();
    assert_eq!(last.stragglers, 3, "floor-bound wave abandons all three stragglers");
    assert!(last.round_ns as f64 / 1e3 <= 500.0);
    assert!(out.crashed.is_empty() && out.eliminated.is_empty());
}

/// Sharded runs scale the quorum to each shard's width: a straggler in
/// one shard stops gating only that shard, and the whole fan-out is
/// quorum-dominated.
#[test]
fn sharded_quorum_gather_is_per_shard() {
    let n = 64usize;
    let k = 4usize;
    let steps = 6usize;
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(63, 50.0)], // lives in shard 3
        ..Default::default()
    };
    let all = run(
        n,
        0,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        steps,
        29,
        "sim",
        k,
        GatherPolicy::All,
        sim.clone(),
    );
    // cluster-level quorum:0.9 -> ceil(0.9 * 16) = 15-of-16 per shard
    let quorum = run(
        n,
        0,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        steps,
        29,
        "sim",
        k,
        GatherPolicy::parse("quorum:0.9", n).expect("parse"),
        sim,
    );
    let all_us = mean_round_us(&all);
    let quorum_us = mean_round_us(&quorum);
    assert!(
        all_us >= 2.0 * quorum_us,
        "per-shard quorum speedup below 2x: all={all_us}us quorum={quorum_us}us"
    );
    assert_eq!(quorum.events.stragglers(), steps, "one abandonment per round");
    // the shard dimension carries the straggler and its round time
    let rec = &quorum.metrics.iterations[0];
    assert_eq!(rec.shard_stats.len(), k);
    assert_eq!(rec.shard_stats.iter().map(|s| s.stragglers).sum::<usize>(), 1);
    assert!(rec.round_ns > 0);
    assert!(quorum.crashed.is_empty() && quorum.eliminated.is_empty());
}
