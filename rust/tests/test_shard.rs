//! Sharded multi-master integration tests, extending PR 1's
//! cross-transport equivalence: at zero latency a K-shard run must be
//! *bit-identical* to the K = 1 run for the same seed (deterministic
//! policy under attack, or any policy fault-free), eliminations must
//! stay shard-local but publish to the global roster, and a shard
//! that loses every worker must have its chunks rescued by survivors.

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::{Event, SimConfig, TrainOutcome};
use r3bft::linalg;

use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};

#[allow(clippy::too_many_arguments)]
fn run(
    n: usize,
    f: usize,
    shards: usize,
    byz: Vec<usize>,
    policy: PolicyKind,
    attack: AttackConfig,
    steps: usize,
    seed: u64,
    sim: SimConfig,
) -> (TrainOutcome, Vec<f32>) {
    let mut cluster = ClusterConfig::new(n, f, seed);
    cluster.byzantine_ids = byz;
    cluster.transport = "sim".into();
    cluster.shards = shards;
    let cfg = ExperimentConfig {
        name: format!("shard-test-{n}x{shards}"),
        cluster,
        policy,
        attack,
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    };
    let d = 16usize;
    let chunk = 8usize;
    let ds = Arc::new(LinRegDataset::generate(2048, d, 0.0, seed));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(seed);
    let opts = MasterOptions { w_star: Some(w_star.clone()), sim, ..Default::default() };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    (master.run().expect("train"), w_star)
}

fn losses(out: &TrainOutcome) -> Vec<u32> {
    out.metrics.iterations.iter().map(|r| r.loss.to_bits()).collect()
}

/// Acceptance: K = 1 vs sharded runs are bit-identical at zero
/// latency under the deterministic (always-audit) policy, liars and
/// all — every tampered chunk is corrected to the true gradient before
/// aggregation, so the parameter trajectory is partition-invariant.
#[test]
fn sharded_run_matches_single_master_bitwise_under_attack() {
    // one liar per future shard so every layout keeps 2*f_s < n_s
    let byz = vec![3usize, 19, 35, 51];
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 3.0 };
    let (k1, w_star) = run(
        64,
        4,
        1,
        byz.clone(),
        PolicyKind::Deterministic,
        attack.clone(),
        120,
        7,
        SimConfig::default(),
    );
    for k in [2usize, 4] {
        let (kk, _) = run(
            64,
            4,
            k,
            byz.clone(),
            PolicyKind::Deterministic,
            attack.clone(),
            120,
            7,
            SimConfig::default(),
        );
        assert_eq!(k1.theta, kk.theta, "K={k}: theta diverged (not bit-identical)");
        assert_eq!(losses(&k1), losses(&kk), "K={k}: loss trajectory diverged");
        let mut e1 = k1.eliminated.clone();
        let mut ek = kk.eliminated.clone();
        e1.sort_unstable();
        ek.sort_unstable();
        assert_eq!(e1, ek, "K={k}: eliminated sets diverged");
        assert_eq!(ek, byz, "K={k}: liars not all eliminated");
        // sharded records carry the shard dimension
        assert!(kk.metrics.iterations[0].shard_stats.len() == k, "K={k}");
        assert!(k1.metrics.iterations[0].shard_stats.is_empty());
    }
    let dist = linalg::dist2(&k1.theta, &w_star);
    assert!(dist < 1e-2, "deterministic sharded run failed to converge: {dist}");
}

/// Fault-free randomized policy: audit coins are shard-local, but
/// honest chunk values are audit-independent, so the trajectory is
/// still bit-identical across K.
#[test]
fn sharded_run_matches_single_master_bitwise_fault_free() {
    let (k1, _) = run(
        64,
        4,
        1,
        vec![],
        PolicyKind::Bernoulli { q: 0.3 },
        AttackConfig::default(),
        80,
        11,
        SimConfig::default(),
    );
    let (k4, _) = run(
        64,
        4,
        4,
        vec![],
        PolicyKind::Bernoulli { q: 0.3 },
        AttackConfig::default(),
        80,
        11,
        SimConfig::default(),
    );
    assert_eq!(k1.theta, k4.theta, "fault-free trajectories diverged");
    assert_eq!(losses(&k1), losses(&k4));
}

/// The ISSUE's acceptance shape at scale: n = 1024 workers in 8
/// shards complete a run on one OS thread, eliminate the injected
/// liars shard-locally, and match K = 1 bit-for-bit.
#[test]
fn sharded_1024_workers_8_shards_matches_k1() {
    // one liar in each of the 8 shards (width 128)
    let byz: Vec<usize> = (0..8).map(|s| s * 128 + 7).collect();
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 2.0 };
    let (k1, _) = run(
        1024,
        8,
        1,
        byz.clone(),
        PolicyKind::Deterministic,
        attack.clone(),
        4,
        13,
        SimConfig::default(),
    );
    let (k8, _) = run(
        1024,
        8,
        8,
        byz.clone(),
        PolicyKind::Deterministic,
        attack,
        4,
        13,
        SimConfig::default(),
    );
    assert_eq!(k1.theta, k8.theta, "n=1024 trajectories diverged");
    assert_eq!(losses(&k1), losses(&k8));
    let mut ek = k8.eliminated.clone();
    ek.sort_unstable();
    assert_eq!(ek, byz, "liars not eliminated shard-locally");
    // every elimination was published to the global roster
    for &w in &byz {
        assert!(
            k8.events.events.iter().any(|e| matches!(
                e,
                Event::RosterEliminated { worker, .. } if *worker == w
            )),
            "worker {w} elimination never published"
        );
    }
}

/// Shard-local identification: liars land in one shard's events with
/// that shard's dimension; other shards stay clean.
#[test]
fn eliminations_are_shard_scoped() {
    // both liars in shard 1 (workers 8..16 of 32, K = 4)
    let byz = vec![9usize, 12];
    let attack = AttackConfig { kind: AttackKind::Noise, p: 1.0, magnitude: 4.0 };
    let (out, w_star) = run(
        32,
        4,
        4,
        byz.clone(),
        PolicyKind::Bernoulli { q: 0.9 },
        attack,
        120,
        17,
        SimConfig::default(),
    );
    let mut ek = out.eliminated.clone();
    ek.sort_unstable();
    assert_eq!(ek, byz, "eliminated: {:?}", out.eliminated);
    // identification events carry shard 1's dimension
    for &w in &byz {
        let shard_hit = out.events.events.iter().any(|e| matches!(
            e,
            Event::Shard { shard: 1, inner } if matches!(
                inner.as_ref(),
                Event::Eliminated { worker, .. } if *worker == w
            )
        ));
        assert!(shard_hit, "worker {w} not eliminated through shard 1");
    }
    // no other shard ever identified anyone
    for s in [0usize, 2, 3] {
        assert!(
            !out
                .events
                .shard_events(s)
                .iter()
                .any(|e| matches!(e, Event::Identified { .. })),
            "shard {s} identified a worker"
        );
    }
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(dist < 1e-2, "dist={dist}");
}

/// Whole-shard crash: every worker of shard 2 crash-stops at iteration
/// 3; the shard is declared dead, its chunks are reassigned to
/// survivors, and training still converges.
#[test]
fn dead_shard_chunks_are_rescued_by_survivors() {
    // n = 16, K = 4 => shard 2 owns workers 8..12
    let sim = SimConfig {
        crash_at: (8..12).map(|w| (w, 3u64)).collect(),
        ..Default::default()
    };
    let (out, w_star) = run(
        16,
        0,
        4,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        120,
        19,
        sim,
    );
    assert_eq!(out.events.dead_shards(), vec![2]);
    let mut crashed = out.crashed.clone();
    crashed.sort_unstable();
    assert_eq!(crashed, (8..12).collect::<Vec<usize>>());
    assert!(out.eliminated.is_empty(), "a crash is not an identification");
    // the rescued iteration still used one gradient per surviving chunk
    // and the run converges on the remaining 12 workers
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(dist < 1e-2, "rescue scenario failed to converge: {dist}");
    assert_eq!(out.metrics.iterations.len(), 120);
    assert!(out.theta.iter().all(|v| v.is_finite()));
}

/// Build-time validation: shard budgets that violate 2 f_s < n_s are
/// rejected before any transport spins up.
#[test]
fn sharded_master_rejects_overloaded_plan() {
    let mut cluster = ClusterConfig::new(16, 4, 1);
    // all four liars in shard 0 (width 4): f_0 = 4 needs 2*4 < 4 — no
    cluster.byzantine_ids = vec![0, 1, 2, 3];
    cluster.transport = "sim".into();
    cluster.shards = 4;
    let cfg = ExperimentConfig {
        name: "overloaded".into(),
        cluster,
        policy: PolicyKind::Deterministic,
        attack: AttackConfig::default(),
        adversary: None,
        train: TrainConfig { steps: 1, lr: 0.1, ..Default::default() },
    };
    let d = 8usize;
    let ds = Arc::new(LinRegDataset::generate(256, d, 0.0, 1));
    let spec = ModelSpec::LinReg { d, batch: 4 };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(1);
    let err = Master::new(cfg, MasterOptions::default(), engine, ds, theta0, 4)
        .err()
        .expect("overloaded shard plan must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("2*f_s < n_s"), "unexpected error: {msg}");
}
