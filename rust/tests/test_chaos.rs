//! Chaos-net soak: the exactness contract must survive a hostile
//! network. A seeded fault layer perturbs every TCP link (drops,
//! delays, duplicates, reorders, corruption, timed partitions) while
//! every frame carries a keyed MAC — and the *decisions* of the
//! protocol (eliminations, θ trajectory, evidence) must be bitwise
//! those of a calm run: chaos may cost time and bytes, never truth.
//!
//! The per-fault matrix (each fault kind × dense/sign wires × flat/
//! sharded) lives in experiment e14 (`e14_fast` runs in tier-1); this
//! file soaks the *combined* storm and the adversarial edges: wrong
//! keys, dead peers, and the chaos-off/auth-off identity with the
//! plain net transport.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;

use r3bft::config::{AttackKind, GatherPolicy, PolicyKind, TransportKind};
use r3bft::coordinator::compress::SignSgd;
use r3bft::coordinator::transport::net::server::{self, ServeOptions};
use r3bft::coordinator::transport::{AuthKey, ChaosSpec};
use r3bft::coordinator::TrainOutcome;
use r3bft::experiments::common::RunSpec;

const AUTH: &str = "test-chaos-secret";

/// Everything at once, at rates the reconnect budget and resend timer
/// always recover from.
const STORM: &str = "drop:0.015,delay:1ms,dup:0.1,reorder:0.2,corrupt:0.015";

/// Host one worker thread; `key`/`chaos` arm its auth and response-path
/// fault injection.
fn spawn_worker(key: Option<&str>, chaos: Option<&str>) -> (String, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let opts = ServeOptions {
        auth: key.map(AuthKey::from_passphrase),
        chaos: chaos.map(|s| ChaosSpec::parse(s).expect("chaos spec")),
    };
    let handle = std::thread::spawn(move || {
        server::serve_with(listener, opts).expect("worker serve");
    });
    (addr, handle)
}

fn spawn_workers(
    n: usize,
    key: Option<&str>,
    chaos: Option<&str>,
) -> (Vec<String>, Vec<JoinHandle<()>>) {
    let mut peers = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        let (addr, h) = spawn_worker(key, chaos);
        peers.push(addr);
        handles.push(h);
    }
    (peers, handles)
}

/// A deterministic-audit sign-flip run: under `GatherPolicy::All` its
/// decisions depend only on gradient *contents*, so any transport that
/// delivers exact contents must reproduce it bitwise.
fn base_spec(n: usize, f: usize, byz: Vec<usize>, steps: usize) -> RunSpec {
    let mut spec = RunSpec::new(n, f, PolicyKind::Deterministic)
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(steps)
        .noise(0.05)
        .gather(GatherPolicy::All);
    spec.byzantine = byz;
    spec
}

/// The exactness contract, asserted on one outcome.
fn assert_exact(label: &str, out: &TrainOutcome, byz: &[usize], steps: usize) {
    assert_eq!(
        out.metrics.iterations.len(),
        steps,
        "{label}: run stopped early (hang or abort)"
    );
    assert!(out.crashed.is_empty(), "{label}: chaos escalated to a crash: {:?}", out.crashed);
    let honest: Vec<usize> =
        out.eliminated.iter().copied().filter(|w| !byz.contains(w)).collect();
    assert!(honest.is_empty(), "{label}: honest workers eliminated: {honest:?}");
    let mut elim = out.eliminated.clone();
    elim.sort_unstable();
    assert_eq!(elim, byz, "{label}: liars not all identified");
    assert_eq!(
        out.events.oracle_faulty_updates(),
        0,
        "{label}: tampered updates entered theta"
    );
}

/// Headline: the combined storm (drops + delays + dups + reorders +
/// corruption, auth on every frame) against a live Byzantine worker
/// changes *nothing* the protocol decides — eliminations, evidence,
/// and θ are bitwise identical to the calm threaded run, while the
/// byte/reconnect accounting shows the storm actually happened.
#[test]
fn combined_storm_is_bit_identical_to_a_calm_run_flat() {
    let (n, f, byz, steps) = (8, 2, vec![2usize, 5], 30);
    let (peers, workers) = spawn_workers(n, Some(AUTH), Some(STORM));
    let recorder = r3bft::trace::Recorder::new();
    let spec = base_spec(n, f, byz.clone(), steps)
        .transport(TransportKind::Net)
        .peers(peers)
        .chaos(STORM)
        .auth_key(AUTH)
        .recorder(recorder.clone());
    let (net, w_star) = spec.run_linreg().expect("chaos net run");
    for h in workers {
        h.join().expect("worker thread");
    }
    assert_exact("storm/flat", &net, &byz, steps);
    for &w in &net.eliminated {
        assert!(
            recorder.evidence_for(w).iter().any(|c| c.complete()),
            "storm/flat: worker {w} eliminated without a complete evidence chain"
        );
    }

    let (calm, _) = base_spec(n, f, byz.clone(), steps)
        .transport(TransportKind::Threaded)
        .run_linreg()
        .expect("threaded run");
    assert_eq!(net.eliminated, calm.eliminated, "storm changed the eliminations");
    assert_eq!(net.theta, calm.theta, "storm changed theta (not bit-identical)");
    assert_eq!(net.events.detections(), calm.events.detections(), "storm changed detections");
    let dist = r3bft::linalg::dist2(&net.theta, &w_star);
    assert!(dist < 1e-2, "storm run failed to converge: dist={dist}");

    // the storm was real: every resent frame is counted, so the wire
    // figure strictly dominates the calm payload estimate; corrupted
    // frames forced at least one session re-establishment
    let net_bytes: u64 = net.metrics.iterations.iter().map(|r| r.bytes_round).sum();
    let calm_bytes: u64 = calm.metrics.iterations.iter().map(|r| r.bytes_round).sum();
    assert!(net_bytes > calm_bytes, "retransmitted bytes uncounted: {net_bytes} <= {calm_bytes}");
    let reconnects: u64 = net.metrics.iterations.iter().map(|r| r.net_reconnects).sum();
    assert!(reconnects > 0, "corruption at 1.5% of ~1k frames must break a session");
}

/// The same storm over sign-compressed wires and a 4-shard fleet: the
/// per-shard protocol cores see exact packed bytes and match the calm
/// sharded run bitwise.
#[test]
fn combined_storm_is_bit_identical_sharded_sign_wires() {
    let (n, f, byz, steps) = (12, 4, vec![1usize, 4, 7, 10], 25);
    let (peers, workers) = spawn_workers(n, Some(AUTH), Some(STORM));
    let spec = base_spec(n, f, byz.clone(), steps)
        .shards(4)
        .compress(Arc::new(SignSgd))
        .transport(TransportKind::Net)
        .peers(peers)
        .chaos(STORM)
        .auth_key(AUTH);
    let (net, _) = spec.run_linreg().expect("chaos sharded run");
    for h in workers {
        h.join().expect("worker thread");
    }
    assert_exact("storm/sharded", &net, &byz, steps);

    let (calm, _) = base_spec(n, f, byz.clone(), steps)
        .shards(4)
        .compress(Arc::new(SignSgd))
        .transport(TransportKind::Threaded)
        .run_linreg()
        .expect("threaded sharded run");
    assert_eq!(net.eliminated, calm.eliminated, "sharded storm changed the eliminations");
    assert_eq!(net.theta, calm.theta, "sharded storm changed theta (not bit-identical)");
}

/// Timed partitions repeatedly knock every link down mid-run; the
/// reconnect budget rides them out (backoff spans the window), the
/// resend timer replays what the outage swallowed, and the outcome is
/// still bitwise calm.
#[test]
fn partition_storms_recover_within_the_reconnect_budget() {
    let (n, f, byz, steps) = (8, 2, vec![2usize, 5], 50);
    let chaos = "partition:40ms@150ms";
    let (peers, workers) = spawn_workers(n, Some(AUTH), Some(chaos));
    let spec = base_spec(n, f, byz.clone(), steps)
        .latency_us(2_000) // keep the run long enough for several windows
        .transport(TransportKind::Net)
        .peers(peers)
        .chaos(chaos)
        .auth_key(AUTH);
    let (net, _) = spec.run_linreg().expect("partition run");
    for h in workers {
        h.join().expect("worker thread");
    }
    assert_exact("partition", &net, &byz, steps);
    let reconnects: u64 = net.metrics.iterations.iter().map(|r| r.net_reconnects).sum();
    assert!(reconnects > 0, "a 40ms outage every 150ms must break at least one session");

    let (calm, _) = base_spec(n, f, byz, steps)
        .latency_us(2_000)
        .transport(TransportKind::Threaded)
        .run_linreg()
        .expect("threaded run");
    assert_eq!(net.theta, calm.theta, "partitions changed theta (not bit-identical)");
}

/// A peer with the wrong key is refused at the handshake — before any
/// per-session state is built — and the master's reconnect budget
/// turns it into an in-band crash-stop, never a hang and never an
/// identification.
#[test]
fn wrong_key_peer_is_refused_and_crash_stops() {
    let (n, f, byz, steps) = (6, 1, vec![2usize], 20);
    let victim = 4usize; // honest, but keyed wrong
    let (mut peers, workers) = spawn_workers(n - 1, Some(AUTH), None);
    let (bad_addr, _detached) = spawn_worker(Some("not-the-fleet-key"), None);
    peers.insert(victim, bad_addr);
    let spec = base_spec(n, f, byz.clone(), steps)
        .transport(TransportKind::Net)
        .peers(peers)
        .auth_key(AUTH);
    let (out, _) = spec.run_linreg().expect("run with one mis-keyed peer");
    // the mis-keyed worker never saw an authentic Shutdown, so its
    // thread is left detached; the correctly-keyed fleet joins clean
    for h in workers {
        h.join().expect("worker thread");
    }
    assert_eq!(out.crashed, vec![victim], "mis-keyed peer must crash-stop in-band");
    assert!(!out.eliminated.contains(&victim), "an auth refusal is not an identification");
    let mut elim = out.eliminated.clone();
    elim.sort_unstable();
    assert_eq!(elim, byz, "the real liar is still identified");
    assert_eq!(out.metrics.iterations.len(), steps, "run must finish every iteration");
    assert_eq!(out.events.oracle_faulty_updates(), 0);
}

/// A link that never comes up exhausts its reconnect budget (exactly
/// max_attempts capped-exponential backoffs) and surfaces as an
/// in-band crash-stop with its chunks reassigned.
#[test]
fn dead_peer_exhausts_the_budget_and_crash_stops() {
    let (n, f, byz, steps) = (6, 1, vec![2usize], 20);
    let victim = 4usize;
    let (mut peers, workers) = spawn_workers(n - 1, Some(AUTH), None);
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind");
        l.local_addr().expect("local addr").to_string()
        // listener dropped: every connect is refused
    };
    peers.insert(victim, dead);
    let spec = base_spec(n, f, byz.clone(), steps)
        .transport(TransportKind::Net)
        .peers(peers)
        .auth_key(AUTH);
    let (out, _) = spec.run_linreg().expect("run with one dead peer");
    for h in workers {
        h.join().expect("worker thread");
    }
    assert_eq!(out.crashed, vec![victim], "dead peer must crash-stop in-band");
    assert!(!out.eliminated.contains(&victim), "a dead link is not an identification");
    let mut elim = out.eliminated.clone();
    elim.sort_unstable();
    assert_eq!(elim, byz, "the liar is still identified around the crash");
    assert_eq!(out.metrics.iterations.len(), steps, "run must finish every iteration");
    assert_eq!(out.events.oracle_faulty_updates(), 0, "no faulty update from a crash");
}

/// Regression guard: with chaos and auth both off, the new plumbing is
/// inert — the net run is bitwise the plain loopback run (which
/// `tests/test_net.rs` pins to threaded/sim), and turning *only* auth
/// on changes bytes on the wire but not one bit of the outcome.
#[test]
fn chaos_off_auth_off_is_the_plain_net_transport() {
    let (n, f, byz, steps) = (8, 2, vec![2usize, 5], 40);
    let (peers_a, workers_a) = spawn_workers(n, None, None);
    let (plain, _) = base_spec(n, f, byz.clone(), steps)
        .transport(TransportKind::Net)
        .peers(peers_a)
        .run_linreg()
        .expect("plain net run");
    for h in workers_a {
        h.join().expect("worker thread");
    }

    let (peers_b, workers_b) = spawn_workers(n, Some(AUTH), None);
    let (authed, _) = base_spec(n, f, byz.clone(), steps)
        .transport(TransportKind::Net)
        .peers(peers_b)
        .auth_key(AUTH)
        .run_linreg()
        .expect("authenticated net run");
    for h in workers_b {
        h.join().expect("worker thread");
    }

    let (calm, _) = base_spec(n, f, byz, steps)
        .transport(TransportKind::Threaded)
        .run_linreg()
        .expect("threaded run");

    assert_eq!(plain.theta, calm.theta, "chaos-off net diverged from threaded");
    assert_eq!(plain.eliminated, calm.eliminated);
    assert_eq!(authed.theta, calm.theta, "auth changed the outcome");
    assert_eq!(authed.eliminated, calm.eliminated);
    // MACs cost 8 bytes per frame and nothing else: no reconnects, and
    // strictly more wire bytes than the unauthenticated run
    assert!(plain.metrics.iterations.iter().all(|r| r.net_reconnects == 0));
    assert!(authed.metrics.iterations.iter().all(|r| r.net_reconnects == 0));
    let plain_bytes: u64 = plain.metrics.iterations.iter().map(|r| r.bytes_round).sum();
    let auth_bytes: u64 = authed.metrics.iterations.iter().map(|r| r.bytes_round).sum();
    assert!(auth_bytes > plain_bytes, "per-frame MACs must show up in the byte accounting");
}
