//! End-to-end coordinator tests on the native engine: the paper's
//! headline guarantees, exercised through the real master/worker
//! protocol (threads, channels, reactive redundancy, elimination).

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::data::{Dataset, LinRegDataset};
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};
use r3bft::linalg;

fn experiment(
    n: usize,
    f: usize,
    byz: Vec<usize>,
    policy: PolicyKind,
    attack: AttackConfig,
    steps: usize,
    seed: u64,
) -> ExperimentConfig {
    let mut cluster = ClusterConfig::new(n, f, seed);
    cluster.byzantine_ids = byz;
    ExperimentConfig {
        name: "test".into(),
        cluster,
        policy,
        attack,
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    }
}

fn run_linreg(
    cfg: ExperimentConfig,
    d: usize,
    chunk: usize,
) -> (r3bft::coordinator::TrainOutcome, Vec<f32>) {
    let ds = Arc::new(LinRegDataset::generate(2048, d, 0.0, cfg.cluster.seed));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(cfg.cluster.seed);
    let opts = MasterOptions { w_star: Some(w_star.clone()), ..Default::default() };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    (master.run().expect("train"), w_star)
}

#[test]
fn vanilla_sgd_without_byzantine_converges() {
    let cfg = experiment(
        8,
        2,
        vec![], // nobody actually Byzantine
        PolicyKind::None,
        AttackConfig::default(),
        150,
        1,
    );
    let (out, w_star) = run_linreg(cfg, 16, 16);
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(dist < 1e-2, "clean run failed to converge: {dist}");
    // efficiency is exactly 1: no audits, no replication
    assert!((out.metrics.average_efficiency() - 1.0).abs() < 1e-12);
    assert_eq!(out.metrics.audit_rate(), 0.0);
}

#[test]
fn vanilla_sgd_is_destroyed_by_attack() {
    let cfg = experiment(
        8,
        2,
        vec![0, 1],
        PolicyKind::None,
        AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 4.0 },
        150,
        2,
    );
    let (out, w_star) = run_linreg(cfg, 16, 16);
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(
        dist > 0.5,
        "the vulnerable baseline should NOT converge under attack (dist={dist})"
    );
    assert!(out.eliminated.is_empty());
}

#[test]
fn deterministic_scheme_exact_convergence_under_attack() {
    for attack in [AttackKind::SignFlip, AttackKind::Noise, AttackKind::SmallBias] {
        let cfg = experiment(
            9,
            2,
            vec![1, 4],
            PolicyKind::Deterministic,
            AttackConfig { kind: attack, p: 1.0, magnitude: 4.0 },
            150,
            3,
        );
        let (out, w_star) = run_linreg(cfg, 16, 16);
        let dist = linalg::dist2(&out.theta, &w_star);
        assert!(dist < 1e-2, "{attack:?}: dist={dist}");
        // persistent attackers must be identified on iteration 0/1
        assert_eq!(out.eliminated.len(), 2, "{attack:?}");
        assert!(out.eliminated.contains(&1) && out.eliminated.contains(&4));
        // no faulty update ever reaches the parameters
        assert_eq!(out.metrics.faulty_update_rate(), 0.0, "{attack:?}");
    }
}

#[test]
fn deterministic_efficiency_matches_one_over_f_plus_one_before_elimination() {
    // attackers never tamper => never identified => every iteration pays
    // the full f+1 proactive replication
    let cfg = experiment(
        9,
        2,
        vec![0, 1],
        PolicyKind::Deterministic,
        AttackConfig { p: 0.0, ..Default::default() },
        50,
        4,
    );
    let (out, _) = run_linreg(cfg, 16, 16);
    let eff = out.metrics.average_efficiency();
    assert!(
        (eff - 1.0 / 3.0).abs() < 1e-9,
        "f=2 deterministic efficiency should be 1/3, got {eff}"
    );
    assert!(out.eliminated.is_empty());
}

#[test]
fn randomized_scheme_identifies_and_converges() {
    let cfg = experiment(
        9,
        2,
        vec![2, 5],
        PolicyKind::Bernoulli { q: 0.3 },
        AttackConfig { kind: AttackKind::SignFlip, p: 0.6, magnitude: 2.0 },
        400,
        5,
    );
    let (out, w_star) = run_linreg(cfg, 16, 16);
    // both persistent tamperers identified almost surely well within 400 iters
    assert_eq!(out.eliminated.len(), 2, "eliminated: {:?}", out.eliminated);
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(dist < 1e-2, "dist={dist}");
    // efficiency must beat the deterministic scheme's 1/3 by far
    let eff = out.metrics.average_efficiency();
    assert!(eff > 0.6, "expected high efficiency, got {eff}");
    // after elimination, audits stop (f_t = 0) so late iters are free
    let late = &out.metrics.iterations[out.metrics.iterations.len() - 10..];
    assert!(late.iter().all(|r| !r.audited));
}

#[test]
fn honest_workers_are_never_eliminated() {
    // heavy auditing + attacks: soundness of identification
    for seed in 0..5u64 {
        let cfg = experiment(
            7,
            3,
            vec![0, 3, 6],
            PolicyKind::Bernoulli { q: 0.8 },
            AttackConfig { kind: AttackKind::Noise, p: 0.5, magnitude: 3.0 },
            120,
            100 + seed,
        );
        let (out, _) = run_linreg(cfg, 8, 8);
        for w in &out.eliminated {
            assert!(
                [0usize, 3, 6].contains(w),
                "honest worker {w} was eliminated (seed {seed})"
            );
        }
    }
}

#[test]
fn adaptive_policy_audits_more_when_loss_high() {
    let cfg = experiment(
        9,
        2,
        vec![0, 1],
        PolicyKind::Adaptive { p_assumed: 0.8 },
        AttackConfig { kind: AttackKind::SignFlip, p: 0.8, magnitude: 2.0 },
        300,
        6,
    );
    let (out, w_star) = run_linreg(cfg, 16, 16);
    assert_eq!(out.eliminated.len(), 2);
    assert!(linalg::dist2(&out.theta, &w_star) < 1e-2);
    // iteration 0: high loss -> λ ≈ 1 -> q* ≈ 1 (audit almost surely);
    // with p = 0.8 both attackers are typically caught immediately,
    // after which f_t = 0 forces q = 0 — the adaptive staircase.
    assert!(out.metrics.iterations[0].q > 0.9, "q_0 = {}", out.metrics.iterations[0].q);
    let t_last = out
        .eliminated
        .iter()
        .map(|&w| out.events.identification_time(w).unwrap())
        .max()
        .unwrap();
    assert!(t_last < 30, "attackers identified late: {t_last}");
    let post = &out.metrics.iterations[(t_last + 1) as usize..];
    assert!(post.iter().all(|r| r.q == 0.0), "q must be 0 once f_t = 0");
}

#[test]
fn selective_policy_with_self_check_identifies() {
    let cfg = experiment(
        8,
        2,
        vec![3, 4],
        PolicyKind::Selective { q_base: 0.3 },
        AttackConfig { kind: AttackKind::Constant, p: 0.7, magnitude: 5.0 },
        300,
        7,
    );
    let ds = Arc::new(LinRegDataset::generate(2048, 16, 0.0, 7));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d: 16, batch: 16 };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(7);
    let opts = MasterOptions {
        self_check: true,
        w_star: Some(w_star.clone()),
        ..Default::default()
    };
    let master = Master::new(cfg, opts, engine, ds, theta0, 16).expect("master");
    let out = master.run().expect("train");
    assert_eq!(out.eliminated.len(), 2, "eliminated {:?}", out.eliminated);
    assert!(linalg::dist2(&out.theta, &w_star) < 1e-2);
}

#[test]
fn deterministic_with_self_check_recomputes_ground_truth_on_demand() {
    // deterministic policy replicates proactively (r = f_t+1), so the
    // detection phase never adds a master self-check copy; the reactive
    // phase must compute one on demand instead of panicking
    let cfg = experiment(
        9,
        2,
        vec![1, 4],
        PolicyKind::Deterministic,
        AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 2.0 },
        60,
        19,
    );
    let ds = Arc::new(LinRegDataset::generate(2048, 16, 0.0, 19));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d: 16, batch: 16 };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(19);
    let opts = MasterOptions {
        self_check: true,
        w_star: Some(w_star.clone()),
        ..Default::default()
    };
    let master = Master::new(cfg, opts, engine, ds, theta0, 16).expect("master");
    let out = master.run().expect("train");
    assert_eq!(out.eliminated.len(), 2, "eliminated {:?}", out.eliminated);
    assert!(out.eliminated.contains(&1) && out.eliminated.contains(&4));
    assert!(linalg::dist2(&out.theta, &w_star) < 1e-2);
}

#[test]
fn intermittent_attacker_is_eventually_identified() {
    // p = 0.15, q = 0.4: survival bound (1 - qp)^t = 0.94^t -> under 600
    // iterations the survival probability is ~1e-16
    let cfg = experiment(
        5,
        1,
        vec![2],
        PolicyKind::Bernoulli { q: 0.4 },
        AttackConfig { kind: AttackKind::SignFlip, p: 0.15, magnitude: 2.0 },
        600,
        8,
    );
    let (out, _) = run_linreg(cfg, 8, 8);
    assert_eq!(out.eliminated, vec![2]);
    let t_id = out.events.identification_time(2).unwrap();
    assert!(t_id < 590, "identified at {t_id}");
}

#[test]
fn efficiency_accounting_is_conservative() {
    // gradients_used <= gradients_computed always; audited iterations
    // strictly dearer
    let cfg = experiment(
        9,
        2,
        vec![0, 1],
        PolicyKind::Bernoulli { q: 0.5 },
        AttackConfig { kind: AttackKind::Noise, p: 0.5, magnitude: 2.0 },
        100,
        9,
    );
    let (out, _) = run_linreg(cfg, 16, 16);
    for r in &out.metrics.iterations {
        assert!(r.gradients_used <= r.gradients_computed, "iter {}", r.iter);
        if !r.audited && r.identified == 0 {
            assert_eq!(
                r.gradients_used, r.gradients_computed,
                "unaudited iteration must cost exactly m (iter {})",
                r.iter
            );
        }
        if r.audited {
            assert!(r.gradients_computed > r.gradients_used, "iter {}", r.iter);
        }
    }
}

#[test]
fn eliminated_workers_receive_no_more_work() {
    let cfg = experiment(
        7,
        2,
        vec![0, 1],
        PolicyKind::Deterministic,
        AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 2.0 },
        40,
        10,
    );
    let (out, _) = run_linreg(cfg, 8, 8);
    assert_eq!(out.eliminated.len(), 2);
    // after both eliminations, efficiency returns to 1 (f_t = 0, r = 1,
    // no audits): §4.1's efficiency staircase
    let late = &out.metrics.iterations[10..];
    for r in late {
        assert!((r.efficiency() - 1.0).abs() < 1e-12, "iter {}: {}", r.iter, r.efficiency());
    }
}

#[test]
fn mlp_under_attack_with_randomized_scheme() {
    use r3bft::data::BlobsDataset;
    let mut cluster = ClusterConfig::new(8, 2, 11);
    cluster.byzantine_ids = vec![6, 7];
    let cfg = ExperimentConfig {
        name: "mlp".into(),
        cluster,
        policy: PolicyKind::Bernoulli { q: 0.4 },
        attack: AttackConfig { kind: AttackKind::Noise, p: 0.8, magnitude: 2.0 },
        adversary: None,
        train: TrainConfig { steps: 250, lr: 0.3, ..Default::default() },
    };
    let ds = Arc::new(BlobsDataset::generate(2048, 8, 3, 4.0, 11));
    let spec = ModelSpec::Mlp { in_dim: 8, hidden: 16, classes: 3, batch: 32 };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(11);
    let master = Master::new(cfg, MasterOptions::default(), engine, ds, theta0, 32).unwrap();
    let out = master.run().expect("train");
    assert_eq!(out.eliminated.len(), 2);
    let first_losses: f32 = out.metrics.losses()[..10].iter().sum::<f32>() / 10.0;
    let last_losses: f32 =
        out.metrics.losses().iter().rev().take(10).sum::<f32>() / 10.0;
    assert!(
        last_losses < 0.5 * first_losses,
        "MLP loss did not fall: {first_losses} -> {last_losses}"
    );
}

#[test]
fn compressed_symbols_protocol_works_end_to_end() {
    use r3bft::coordinator::compress::TopK;
    let cfg = experiment(
        9,
        2,
        vec![0, 1],
        PolicyKind::Bernoulli { q: 0.4 },
        AttackConfig { kind: AttackKind::SignFlip, p: 0.8, magnitude: 2.0 },
        300,
        21,
    );
    let ds = Arc::new(LinRegDataset::generate(2048, 16, 0.0, 21));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d: 16, batch: 16 };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(21);
    let opts = MasterOptions {
        w_star: Some(w_star.clone()),
        compressor: Some(Arc::new(TopK { k: 8 })),
        ..Default::default()
    };
    let master = Master::new(cfg, opts, engine, ds, theta0, 16).unwrap();
    let out = master.run().unwrap();
    // detection + identification work on the compressed wire form
    assert_eq!(out.eliminated.len(), 2, "eliminated {:?}", out.eliminated);
    // top-8 of 16 coords still converges on linreg (error-free sparsity
    // near the optimum); generous tolerance for the lossy path
    assert!(
        linalg::dist2(&out.theta, &w_star) < 0.05,
        "dist {}",
        linalg::dist2(&out.theta, &w_star)
    );
}

#[test]
fn hybrid_filter_bounds_unaudited_damage() {
    use r3bft::baselines::filters::MedianFilter;
    let mk = |filter: Option<Arc<dyn r3bft::baselines::GradientFilter>>| {
        let cfg = experiment(
            9,
            2,
            vec![7, 8],
            PolicyKind::Bernoulli { q: 0.05 },
            AttackConfig { kind: AttackKind::Noise, p: 0.9, magnitude: 3.0 },
            200,
            33,
        );
        let ds = Arc::new(LinRegDataset::generate(2048, 16, 0.0, 33));
        let w_star = ds.w_star.clone();
        let spec = ModelSpec::LinReg { d: 16, batch: 16 };
        let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
        let theta0 = spec.init_theta(33);
        let opts = MasterOptions {
            w_star: Some(w_star),
            unaudited_filter: filter,
            ..Default::default()
        };
        let master = Master::new(cfg, opts, engine, ds, theta0, 16).unwrap();
        master.run().unwrap()
    };
    let plain = mk(None);
    let hybrid = mk(Some(Arc::new(MedianFilter)));
    let mean_dist = |out: &r3bft::coordinator::TrainOutcome| {
        out.metrics
            .iterations
            .iter()
            .filter_map(|r| r.dist_to_opt)
            .map(|d| d as f64)
            .sum::<f64>()
            / out.metrics.iterations.len() as f64
    };
    assert!(
        mean_dist(&hybrid) < 0.5 * mean_dist(&plain),
        "hybrid {} vs plain {}",
        mean_dist(&hybrid),
        mean_dist(&plain)
    );
}
