//! Pipelined-round correctness: a depth-D run must apply θ updates in
//! strict iteration order, so
//!
//! * depth 1 is the unpipelined protocol by construction, and a
//!   fault-free depth-2 run (where every speculation is confirmed) is
//!   bit-identical to it on both transports and any shard count;
//! * with liars forcing a reissue every round (Deterministic policy,
//!   no_eliminate holds the active set fixed), depths 1/2/3 are
//!   bit-identical — the mid-pipeline catch retires the provisional
//!   wave and resubmits on the exact θ;
//! * at the `ProtocolCore` level, late deliveries of a reissued
//!   (dead) wave are dropped by wave id, never ingested.

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, GatherPolicy, PolicyKind,
    TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::protocol::{ProtocolConfig, ProtocolCore};
use r3bft::coordinator::{EventLog, FaultCheckPolicy, LatencyModel, SimConfig, SimTransport, TrainOutcome};
use r3bft::data::{Dataset, LinRegDataset};
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};

#[allow(clippy::too_many_arguments)]
fn run(
    n: usize,
    f: usize,
    byz: Vec<usize>,
    policy: PolicyKind,
    attack: AttackConfig,
    steps: usize,
    seed: u64,
    transport: &str,
    shards: usize,
    pipeline: usize,
    no_eliminate: bool,
    sim: SimConfig,
) -> TrainOutcome {
    let mut cluster = ClusterConfig::new(n, f, seed);
    cluster.byzantine_ids = byz;
    cluster.transport = transport.into();
    cluster.shards = shards;
    cluster.pipeline = pipeline;
    let cfg = ExperimentConfig {
        name: "pipeline-test".into(),
        cluster,
        policy,
        attack,
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    };
    let d = 16usize;
    let chunk = 8usize;
    let ds = Arc::new(LinRegDataset::generate(2048, d, 0.0, seed));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(seed);
    let opts = MasterOptions { no_eliminate, sim, ..Default::default() };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    master.run().expect("train")
}

fn losses_bits(out: &TrainOutcome) -> Vec<u32> {
    out.metrics.iterations.iter().map(|r| r.loss.to_bits()).collect()
}

/// Fault-free runs confirm every speculation, so the whole pipeline
/// overlap is invisible in values: depth 2 must match depth 1
/// bit-for-bit on both transports and for K ∈ {1, 4}.
#[test]
fn fault_free_depth2_is_bit_identical_to_depth1() {
    for transport in ["threaded", "sim"] {
        for shards in [1usize, 4] {
            let base = run(
                16,
                2,
                vec![],
                PolicyKind::Bernoulli { q: 0.3 },
                AttackConfig::default(),
                60,
                11,
                transport,
                shards,
                1,
                false,
                SimConfig::default(),
            );
            let piped = run(
                16,
                2,
                vec![],
                PolicyKind::Bernoulli { q: 0.3 },
                AttackConfig::default(),
                60,
                11,
                transport,
                shards,
                2,
                false,
                SimConfig::default(),
            );
            let label = format!("{transport} K={shards}");
            assert_eq!(base.theta, piped.theta, "{label}: theta diverged");
            assert_eq!(losses_bits(&base), losses_bits(&piped), "{label}: losses diverged");
            assert_eq!(base.eliminated, piped.eliminated, "{label}");
            // every pipelined row reports its configured depth
            assert!(piped.metrics.iterations.iter().all(|r| r.pipeline_depth == 2), "{label}");
            assert!(base.metrics.iterations.iter().all(|r| r.pipeline_depth == 1), "{label}");
        }
    }
}

/// θ-application order == iteration order at any depth, including a
/// liar caught mid-pipeline: under the always-audit policy every round
/// corrects its tampering and forces a reissue of the speculative
/// wave, and with `no_eliminate` the active set (hence the sample
/// stream) never changes — so depths 1, 2, and 3 must be bit-identical
/// despite a reissue in every single round.
#[test]
fn liar_catch_mid_pipeline_reissues_to_the_depth1_trajectory() {
    let byz = vec![3usize, 7];
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 3.0 };
    let runs: Vec<TrainOutcome> = [1usize, 2, 3]
        .iter()
        .map(|&depth| {
            run(
                9,
                2,
                byz.clone(),
                PolicyKind::Deterministic,
                attack.clone(),
                50,
                13,
                "sim",
                1,
                depth,
                true,
                SimConfig::default(),
            )
        })
        .collect();
    for (i, piped) in runs.iter().enumerate().skip(1) {
        let depth = i + 1;
        assert_eq!(runs[0].theta, piped.theta, "depth {depth}: theta diverged");
        assert_eq!(
            losses_bits(&runs[0]),
            losses_bits(piped),
            "depth {depth}: losses diverged"
        );
        // the liars kept lying (no_eliminate), so every audit caught
        // tampering and corrected θ away from the speculation — the
        // depth-1 trajectory survived a reissue under every round
        assert!(
            piped.metrics.iterations.iter().all(|r| r.faults_detected > 0),
            "depth {depth}: scenario must catch tampering every round"
        );
    }
}

/// Depth-1 pipelined config routes through the classic sequential
/// driver: identical to the default config byte-for-byte, with liars
/// and eliminations.
#[test]
fn depth1_equals_default_with_eliminations() {
    let byz = vec![2usize, 5];
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 0.8, magnitude: 2.0 };
    for transport in ["threaded", "sim"] {
        let a = run(
            9,
            2,
            byz.clone(),
            PolicyKind::Bernoulli { q: 0.4 },
            attack.clone(),
            80,
            17,
            transport,
            1,
            1,
            false,
            SimConfig::default(),
        );
        let b = run(
            9,
            2,
            byz.clone(),
            PolicyKind::Bernoulli { q: 0.4 },
            attack.clone(),
            80,
            17,
            transport,
            1,
            1,
            false,
            SimConfig::default(),
        );
        assert_eq!(a.theta, b.theta, "{transport}");
        assert_eq!(a.eliminated, b.eliminated, "{transport}");
    }
}

/// ProtocolCore-level dead-wave drain: begin a round on a provisional
/// θ_A, reissue it on θ_B before collecting, and drive it to
/// completion under latency (so θ_A deliveries land *during* the
/// θ_B wave's gather). Every chosen symbol must be the gradient at
/// θ_B — the retired wave's deliveries are dropped by wave id, never
/// ingested.
#[test]
fn reissued_wave_late_deliveries_are_dropped() {
    let n = 6usize;
    let d = 16usize;
    let cs = 8usize;
    let seed = 23u64;
    let ds = LinRegDataset::generate(1024, d, 0.0, seed);
    let spec = ModelSpec::LinReg { d, batch: cs };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec));
    let sim = SimConfig { latency: LatencyModel::Fixed { us: 500 }, ..Default::default() };
    let transport = SimTransport::new(n, engine.clone(), |_| None, None, sim);
    let policy = FaultCheckPolicy::new(PolicyKind::Bernoulli { q: 0.0 }, n, seed);
    let mut core = ProtocolCore::new(
        Box::new(transport),
        policy,
        ProtocolConfig {
            f: 1,
            seed,
            chunk_size: cs,
            self_check: false,
            tol: 0.0,
            no_eliminate: false,
            compressor: None,
            gather: GatherPolicy::All,
            pipeline: 2,
        },
    );
    let theta_a = Arc::new(vec![0.25f32; d]);
    let theta_b = Arc::new(vec![-1.5f32; d]);
    let mut events = EventLog::default();

    core.begin_round_sampled(0, &theta_a, &ds).expect("begin");
    // the speculation was wrong: retire wave A, resubmit on θ_B
    core.reissue_round(0, &theta_b, &ds).expect("reissue");
    core.collect_proactive(0, &theta_b, &ds, &mut events).expect("collect");

    let round = core.pending_round(0).expect("collected round");
    assert!(round.nchunks() > 0);
    for c in 0..round.nchunks() {
        let sym = round.chosen(c);
        let batch = ds.batch(&round.assignment.chunks[c]);
        let want = engine.grad(&theta_b, &batch).expect("grad").grad;
        assert_eq!(
            sym.grad, want,
            "chunk {c}: ingested a dead-wave (θ_A) symbol from worker {}",
            sym.worker
        );
        let stale = engine.grad(&theta_a, &batch).expect("grad").grad;
        assert_ne!(sym.grad, stale, "chunk {c}: θ_A and θ_B gradients must differ");
    }
    let out = core
        .finish_round(0, &theta_b, &ds, engine.as_ref(), &mut events)
        .expect("finish");
    assert_eq!(out.faults_detected, 0, "dead-wave deliveries mistaken for faults");
}
