//! Cross-transport integration tests: the threaded pool and the
//! virtual-time simulator must be *bit-identical* for the same seed
//! and config (sim at zero latency), and the simulator must scale to
//! four-digit worker counts and model crash-drop scenarios the
//! threaded pool cannot.

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::{SimConfig, TrainOutcome};
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};
use r3bft::linalg;

fn run(
    n: usize,
    f: usize,
    byz: Vec<usize>,
    policy: PolicyKind,
    attack: AttackConfig,
    steps: usize,
    seed: u64,
    transport: &str,
    sim: SimConfig,
) -> (TrainOutcome, Vec<f32>) {
    let mut cluster = ClusterConfig::new(n, f, seed);
    cluster.byzantine_ids = byz;
    cluster.transport = transport.into();
    let cfg = ExperimentConfig {
        name: "transport-test".into(),
        cluster,
        policy,
        attack,
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    };
    let d = 16usize;
    let chunk = 8usize;
    let ds = Arc::new(LinRegDataset::generate(2048, d, 0.0, seed));
    let w_star = ds.w_star.clone();
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(seed);
    let opts = MasterOptions { w_star: Some(w_star.clone()), sim, ..Default::default() };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    (master.run().expect("train"), w_star)
}

/// Acceptance: same seed + config => identical `eliminated` and bitwise
/// identical final `theta` across transports (sim at zero latency).
#[test]
fn sim_and_threaded_transports_are_bit_identical() {
    let scenarios: Vec<(PolicyKind, AttackConfig, Vec<usize>)> = vec![
        (
            PolicyKind::Bernoulli { q: 0.3 },
            AttackConfig { kind: AttackKind::SignFlip, p: 0.6, magnitude: 2.0 },
            vec![2, 5],
        ),
        (
            PolicyKind::Deterministic,
            AttackConfig { kind: AttackKind::Noise, p: 1.0, magnitude: 3.0 },
            vec![1, 4],
        ),
        (PolicyKind::None, AttackConfig::default(), vec![]),
    ];
    for (policy, attack, byz) in scenarios {
        let label = format!("{policy:?}/{:?}", attack.kind);
        let (threaded, _) = run(
            9,
            2,
            byz.clone(),
            policy.clone(),
            attack.clone(),
            120,
            7,
            "threaded",
            SimConfig::default(),
        );
        let (sim, _) = run(9, 2, byz, policy, attack, 120, 7, "sim", SimConfig::default());
        assert_eq!(threaded.eliminated, sim.eliminated, "{label}: eliminated diverged");
        assert_eq!(threaded.theta, sim.theta, "{label}: theta diverged (not bit-identical)");
        assert_eq!(
            threaded.metrics.average_efficiency(),
            sim.metrics.average_efficiency(),
            "{label}: efficiency accounting diverged"
        );
        assert_eq!(threaded.events.audits(), sim.events.audits(), "{label}");
        assert_eq!(threaded.events.detections(), sim.events.detections(), "{label}");
    }
}

/// Acceptance: n = 1024 simulated workers complete a protocol run on
/// the caller's thread — no 1024-thread pool. (The threaded transport
/// at this n would need an OS thread per worker; the sim needs zero.)
#[test]
fn sim_scales_to_1024_workers_without_os_threads() {
    let n = 1024usize;
    let mut cluster = ClusterConfig::new(n, 3, 11);
    cluster.byzantine_ids = vec![100, 500, 900];
    cluster.transport = "sim".into();
    let cfg = ExperimentConfig {
        name: "sim-1024".into(),
        cluster,
        policy: PolicyKind::Bernoulli { q: 0.5 },
        attack: AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 2.0 },
        adversary: None,
        train: TrainConfig { steps: 3, lr: 0.1, ..Default::default() },
    };
    let d = 4usize;
    let chunk = 2usize;
    let ds = Arc::new(LinRegDataset::generate(4096, d, 0.0, 11));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(11);
    let master =
        Master::new(cfg, MasterOptions::default(), engine, ds, theta0, chunk).expect("master");
    let out = master.run().expect("train");
    assert_eq!(out.metrics.iterations.len(), 3);
    assert!(out.theta.iter().all(|v| v.is_finite()));
    // q = 0.5 over 3 iterations with p = 1 attackers: detection is
    // probable but not guaranteed — only soundness is asserted
    for w in &out.eliminated {
        assert!([100usize, 500, 900].contains(w), "honest worker {w} eliminated");
    }
}

/// Crash-drop scenario: a crash-stopped worker's chunks are reassigned
/// (every chunk keeps >= 1 copy), the worker is retired without being
/// *identified*, and training still converges.
#[test]
fn sim_crash_drop_reassigns_chunks_and_converges() {
    let sim = SimConfig { crash_at: vec![(3, 5)], ..Default::default() };
    let (out, w_star) = run(
        6,
        1,
        vec![],
        PolicyKind::None,
        AttackConfig::default(),
        200,
        13,
        "sim",
        sim,
    );
    assert_eq!(out.crashed, vec![3]);
    assert!(out.eliminated.is_empty(), "a crash is not an identification");
    assert_eq!(out.events.crashes(), 1);
    // iteration 5 reassigns the orphaned chunk; its record carries the
    // crash count, and the accounting stays exact (the crashed worker
    // never computed — the message vanished before compute)
    let rec5 = &out.metrics.iterations[5];
    assert_eq!(rec5.crashed, 1);
    assert_eq!(rec5.gradients_computed, rec5.gradients_used);
    // from iteration 6 on the cluster is 5 workers; every iteration
    // still uses one gradient per chunk and converges exactly
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(dist < 1e-2, "crash scenario failed to converge: {dist}");
}

/// Byzantine identification keeps working after an unrelated crash.
#[test]
fn sim_crash_and_byzantine_together() {
    let sim = SimConfig { crash_at: vec![(0, 10)], ..Default::default() };
    let (out, w_star) = run(
        9,
        2,
        vec![6],
        PolicyKind::Bernoulli { q: 0.5 },
        AttackConfig { kind: AttackKind::SignFlip, p: 1.0, magnitude: 3.0 },
        200,
        17,
        "sim",
        sim,
    );
    assert_eq!(out.crashed, vec![0]);
    assert_eq!(out.eliminated, vec![6], "attacker must still be identified");
    let dist = linalg::dist2(&out.theta, &w_star);
    assert!(dist < 1e-2, "dist={dist}");
}
