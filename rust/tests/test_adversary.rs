//! Exactness under coordinated, protocol-aware adversaries: the
//! paper's claim (2f < n => every persistently-tampering worker is
//! eventually identified and eliminated, and no honest worker ever
//! is) must survive every shipped strategy — single-master and
//! sharded, threaded and simulated.
//!
//! The strategies are configured to *persist* (short warm-ups and
//! dormancies), so each run must end in one of the paper's two
//! terminal states: all colluders eliminated, or (for strategies that
//! go fully silent) zero tampered updates. Either way the tail of the
//! run is fault-free.

use r3bft::config::{AdversaryKind, AttackKind, GatherPolicy, PolicyKind, TransportKind};
use r3bft::coordinator::{Event, LatencyModel, SimConfig, TrainOutcome};
use r3bft::experiments::common::RunSpec;

/// Byzantine ids spread across shards so every K in {1, 4} keeps
/// 2 f_s < n_s (n must be a multiple of 4).
fn byz_ids(n: usize) -> Vec<usize> {
    vec![n / 4 + 1, n / 2 + 3]
}

/// Strategy variants tuned to persist within a short test horizon.
fn strategies() -> Vec<AdversaryKind> {
    vec![
        AdversaryKind::AssignmentAware,
        AdversaryKind::Sleeper { warmup: 8 },
        AdversaryKind::AuditEvader { cooldown: 4 },
        AdversaryKind::LatencyMimic,
        AdversaryKind::ShardEquivocator,
    ]
}

fn run(kind: AdversaryKind, n: usize, transport: TransportKind, shards: usize) -> TrainOutcome {
    let mut spec = RunSpec::new(n, 2, PolicyKind::Bernoulli { q: 0.4 })
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(80)
        .noise(0.05) // keep gradients off bit-zero (paper footnote 2)
        .transport(transport)
        .shards(shards)
        .gather(GatherPolicy::All)
        .adversary(kind);
    spec.byzantine = byz_ids(n);
    let (out, _) = spec.run_linreg().expect("adversarial run");
    out
}

/// The exactness contract for one finished run.
fn assert_exactness(kind: AdversaryKind, n: usize, out: &TrainOutcome) {
    let byz = byz_ids(n);
    // (1) no honest worker is ever eliminated
    for w in &out.eliminated {
        assert!(
            byz.contains(w),
            "{:?} n={n}: honest worker {w} eliminated ({:?})",
            kind,
            out.eliminated
        );
    }
    // (2) every colluder is identified and eliminated: all shipped
    // strategies keep tampering under r = 1 audits (warm-ups and
    // dormancies are finite; the equivocator re-targets after each
    // elimination), and with q = 0.4 over 80 rounds a persistent liar
    // escaping identification has vanishing probability
    let mut eliminated = out.eliminated.clone();
    eliminated.sort_unstable();
    assert_eq!(
        eliminated, byz,
        "{:?} n={n}: persistently-tampering colluders not all eliminated",
        kind
    );
    // (3) the run is fault-free after the last elimination: no
    // tampered chunk value enters theta once the liars are gone
    let last_elim = out
        .events
        .flat()
        .filter_map(|e| match e {
            Event::Eliminated { iter, .. } => Some(*iter),
            _ => None,
        })
        .max()
        .expect("eliminations present");
    let late_faulty = out
        .events
        .flat()
        .filter(|e| matches!(e, Event::OracleFaultyUpdate { iter } if *iter > last_elim))
        .count();
    assert_eq!(
        late_faulty, 0,
        "{:?} n={n}: tampered updates after the last elimination",
        kind
    );
}

#[test]
fn exactness_sim_n16_single_and_sharded() {
    for kind in strategies() {
        for shards in [1usize, 4] {
            let out = run(kind, 16, TransportKind::Sim, shards);
            assert_exactness(kind, 16, &out);
        }
    }
}

#[test]
fn exactness_threaded_n16_single_and_sharded() {
    for kind in strategies() {
        for shards in [1usize, 4] {
            let out = run(kind, 16, TransportKind::Threaded, shards);
            assert_exactness(kind, 16, &out);
        }
    }
}

#[test]
fn exactness_sim_n64_single_and_sharded() {
    for kind in strategies() {
        for shards in [1usize, 4] {
            let out = run(kind, 64, TransportKind::Sim, shards);
            assert_exactness(kind, 64, &out);
        }
    }
}

#[test]
fn exactness_threaded_n64_single_and_sharded() {
    for kind in strategies() {
        for shards in [1usize, 4] {
            let out = run(kind, 64, TransportKind::Threaded, shards);
            assert_exactness(kind, 64, &out);
        }
    }
}

#[test]
fn sleeper_is_costlier_to_identify_than_stateless_at_equal_q() {
    // nothing can be identified before the sleeper's first tamper, so
    // its identification time is >= warmup by construction; a stateless
    // p = 1 liar under the same q = 0.5 budget falls at the first
    // audited round (P(no audit in 20 rounds) = 0.5^20)
    let n = 16;
    let warmup = 20u64;
    let mut sleeper = RunSpec::new(n, 2, PolicyKind::Bernoulli { q: 0.5 })
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(120)
        .noise(0.05)
        .transport(TransportKind::Sim)
        .adversary(AdversaryKind::Sleeper { warmup });
    sleeper.byzantine = byz_ids(n);
    let (out_sleeper, _) = sleeper.run_linreg().unwrap();

    let mut stateless = RunSpec::new(n, 2, PolicyKind::Bernoulli { q: 0.5 })
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(120)
        .noise(0.05)
        .transport(TransportKind::Sim);
    stateless.byzantine = byz_ids(n);
    let (out_stateless, _) = stateless.run_linreg().unwrap();

    let last_id = |out: &TrainOutcome| {
        byz_ids(n)
            .iter()
            .map(|&w| out.events.identification_time(w).expect("identified"))
            .max()
            .unwrap()
    };
    let t_sleeper = last_id(&out_sleeper);
    let t_stateless = last_id(&out_stateless);
    assert!(
        t_sleeper >= warmup,
        "sleeper identified at {t_sleeper}, before its strike at {warmup}"
    );
    assert!(
        t_sleeper > t_stateless,
        "sleeper ({t_sleeper}) must outlive the stateless liar ({t_stateless}) \
         at equal q budget"
    );
}

#[test]
fn latency_mimic_stalls_rounds_but_stays_under_the_gates() {
    // sim with a real base latency: the mimic fakes its sub-gate stall
    // (~2.9 ms) on top of the 100 us wave, gating every pre-elimination
    // round, and sheds it after elimination
    let n = 16;
    let mut spec = RunSpec::new(n, 2, PolicyKind::Bernoulli { q: 0.4 })
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(60)
        .noise(0.05)
        .transport(TransportKind::Sim)
        .adversary(AdversaryKind::LatencyMimic)
        .sim(SimConfig { latency: LatencyModel::Fixed { us: 100 }, ..Default::default() });
    spec.byzantine = byz_ids(n);
    let (out, _) = spec.run_linreg().unwrap();
    assert_exactness(AdversaryKind::LatencyMimic, n, &out);
    // round 0: the mimic's stall dominates the All-gather round time
    let first = &out.metrics.iterations[0];
    assert!(
        first.round_ns >= 2_900_000,
        "mimic stall missing from round 0 ({} ns)",
        first.round_ns
    );
    // after the last elimination the rounds run at base latency again
    let last = out.metrics.iterations.last().unwrap();
    assert!(
        last.round_ns < 2_000_000,
        "stall persisted after elimination ({} ns)",
        last.round_ns
    );
}

#[test]
fn equivocator_strikes_one_shard_at_a_time() {
    // K = 4, one colluder in shard 1 and one in shard 2: the
    // equivocator's pressure metric targets the tied shards lowest-id
    // first, so the shard-1 colluder must fall before the shard-2
    // colluder ever tells a lie
    let n = 16;
    let byz = byz_ids(n); // [5, 11] -> shards 1 and 2 at K = 4
    let mut spec = RunSpec::new(n, 2, PolicyKind::Bernoulli { q: 0.4 })
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(80)
        .noise(0.05)
        .transport(TransportKind::Sim)
        .shards(4)
        .adversary(AdversaryKind::ShardEquivocator);
    spec.byzantine = byz.clone();
    let (out, _) = spec.run_linreg().unwrap();
    assert_exactness(AdversaryKind::ShardEquivocator, n, &out);
    let t_first = out.events.identification_time(byz[0]).unwrap();
    let t_second = out.events.identification_time(byz[1]).unwrap();
    assert!(
        t_first < t_second,
        "target shard's colluder ({t_first}) must fall before the next ({t_second})"
    );
}
