//! Flight-recorder integration tests: determinism of every export,
//! pipelined wave overlap + dead-wave reissue visibility, forensic
//! bundles with complete evidence chains on elimination, and the
//! JSONL event stream's round-trip fidelity.

use std::collections::BTreeSet;
use std::io::Write;
use std::sync::{Arc, Mutex};

use r3bft::config::{AttackKind, PolicyKind, TransportKind};
use r3bft::coordinator::{Event, LatencyModel, SimConfig, TrainOutcome};
use r3bft::experiments::common::RunSpec;
use r3bft::trace::{Recorder, WaveSpan};
use r3bft::util::json::Json;

/// A sign-flipping pair of Byzantine workers under the deterministic
/// audit scheme, on the sim transport (virtual clock ⇒ byte-stable
/// trace timestamps), with a recorder attached.
fn traced(
    shards: usize,
    pipeline: usize,
    steps: usize,
    seed: u64,
) -> (TrainOutcome, Arc<Recorder>) {
    let rec = Recorder::new();
    let mut spec = RunSpec::new(8, 2, PolicyKind::Deterministic)
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(steps)
        .seed(seed)
        .noise(0.05)
        .transport(TransportKind::Sim)
        .shards(shards)
        .pipeline(pipeline)
        .sim(SimConfig { latency: LatencyModel::Fixed { us: 100 }, ..Default::default() })
        .recorder(rec.clone());
    spec.byzantine = vec![3, 7];
    let (out, _) = spec.run_linreg().expect("traced run");
    (out, rec)
}

/// Same seed ⇒ byte-identical exporters, single-core edition.
#[test]
fn same_seed_exports_are_byte_identical() {
    let (_, a) = traced(1, 1, 30, 42);
    let (_, b) = traced(1, 1, 30, 42);
    let trace = a.chrome_trace();
    assert!(trace.contains("\"traceEvents\""), "chrome trace shape");
    assert_eq!(trace, b.chrome_trace(), "chrome trace must be deterministic");
    let jsonl = a.events_jsonl();
    assert!(!jsonl.is_empty(), "events stream must be non-empty");
    assert_eq!(jsonl, b.events_jsonl(), "events stream must be deterministic");
    assert_eq!(a.prometheus(), b.prometheus(), "metrics must be deterministic");
    assert_eq!(a.flight_json(), b.flight_json(), "bundles must be deterministic");
}

/// Same seed ⇒ byte-identical exporters under sharding *and*
/// pipelining (the hardest interleaving the runtime offers).
#[test]
fn sharded_pipelined_exports_are_byte_identical() {
    let (_, a) = traced(2, 2, 25, 7);
    let (_, b) = traced(2, 2, 25, 7);
    assert_eq!(a.chrome_trace(), b.chrome_trace());
    assert_eq!(a.events_jsonl(), b.events_jsonl());
    assert_eq!(a.prometheus(), b.prometheus());
    assert_eq!(a.flight_json(), b.flight_json());
    // both shards must show up in the span stream
    let shards: BTreeSet<usize> = a.wave_spans().iter().map(|w| w.shard).collect();
    assert_eq!(shards, BTreeSet::from([0, 1]));
}

fn overlaps(a: &WaveSpan, b: &WaveSpan) -> bool {
    a.start_ns < b.end_ns && b.start_ns < a.end_ns
}

/// Depth-2 pipelining on the transport clock: round t+1's speculative
/// proactive wave must visibly overlap round t's audit waves, and the
/// sign-flip liars force speculation misses whose dead waves show up
/// as `reissued` spans (plus a reissue counter and forensic bundle).
#[test]
fn pipelined_trace_shows_overlapping_waves_and_reissues() {
    let steps = 20;
    let (_, rec) = traced(1, 2, steps, 42);
    let waves = rec.wave_spans();
    assert!(!waves.is_empty());
    assert!(waves.iter().all(|w| w.closed), "no wave may be left open at run end");
    assert!(
        waves.iter().any(|w| w.reissued),
        "a caught liar must retire the speculative wave as reissued"
    );
    assert!(rec.counter("r3bft_reissues_total") > 0);
    let cross_iter_overlap = waves.iter().enumerate().any(|(i, a)| {
        waves[i + 1..].iter().any(|b| a.iter != b.iter && overlaps(a, b))
    });
    assert!(
        cross_iter_overlap,
        "depth-2 pipelining must produce overlapping wave spans of different iterations"
    );
    assert_eq!(rec.round_spans().len(), steps, "one round span per iteration");
    assert!(rec.counter("r3bft_deliveries_total") > 0);
    assert!(
        rec.bundles().iter().any(|b| b.reason.contains("reissue")),
        "dead-wave reissue must dump a forensic bundle"
    );
}

/// Every elimination must leave a forensic bundle whose evidence chain
/// carries the audited chunk, the disagreeing packed-symbol hashes,
/// the reactive top-up, and the vote tally naming the liar.
#[test]
fn elimination_dumps_bundle_with_complete_evidence_chain() {
    let steps = 40;
    let (out, rec) = traced(1, 1, steps, 42);
    assert!(!out.eliminated.is_empty(), "sign-flip liars must be eliminated");

    for &w in &out.eliminated {
        let chains = rec.evidence_for(w);
        let chain = chains
            .iter()
            .find(|c| c.complete())
            .unwrap_or_else(|| panic!("worker {w} eliminated without a complete chain"));
        assert!(chain.audited, "the exposing audit decision must be recorded");
        let det = chain.detection.as_ref().expect("detection evidence");
        assert!(det.hashes.len() >= 2, "detection needs at least two copies to disagree");
        let distinct: BTreeSet<u64> = det.hashes.iter().map(|(_, h)| *h).collect();
        assert!(distinct.len() >= 2, "disagreeing copies must hash differently");
        assert!(!chain.topup.is_empty(), "reactive top-up workers must be recorded");
        let vote = chain.vote.as_ref().expect("vote evidence");
        let copies: usize = vote.tally.iter().map(|(_, n)| *n).sum();
        assert!(copies >= 3, "the vote must span 2f_t+1 copies");
        assert!(vote.liars.contains(&w), "the vote must name the eliminated worker");
        assert!(chain.eliminated.contains(&w));
    }

    let bundle = rec
        .bundles()
        .into_iter()
        .find(|b| b.reason.contains("eliminated"))
        .expect("an elimination must dump a forensic bundle");
    assert!(!bundle.ring.is_empty(), "the bundle must carry the flight-recorder ring");
    assert!(bundle.evidence.iter().any(|c| c.complete()));

    assert_eq!(rec.counter("r3bft_rounds_total"), steps as u64);
    assert_eq!(rec.counter("r3bft_eliminated_total"), out.eliminated.len() as u64);
    assert!(rec.counter("r3bft_detections_total") >= 1);
    let prom = rec.prometheus();
    assert!(prom.contains("# TYPE r3bft_rounds_total counter"));
    assert!(prom.contains(&format!("r3bft_eliminated_total {}", out.eliminated.len())));
    assert!(prom.contains("r3bft_round_time_ns_bucket{le=\"+Inf\"}"));
}

/// Every JSONL line must parse, round-trip through `Event::from_json`,
/// and carry a strictly increasing `seq` starting at zero.
#[test]
fn events_jsonl_round_trips_with_ordered_seqs() {
    let (_, rec) = traced(1, 1, 20, 42);
    let jsonl = rec.events_jsonl();
    let mut n = 0u64;
    for (i, line) in jsonl.lines().enumerate() {
        let parsed = Json::parse(line).expect("every line is one JSON object");
        let seq = parsed.req("seq").unwrap().as_f64().unwrap() as u64;
        assert_eq!(seq, i as u64, "seq must be dense and strictly increasing");
        assert!(parsed.req("at_ns").unwrap().as_f64().is_some());
        Event::from_json(parsed.req("event").unwrap())
            .unwrap_or_else(|e| panic!("line {i} does not round-trip: {e:?}"));
        n += 1;
    }
    assert!(n > 0);
    assert_eq!(n, rec.stamped_events().len() as u64);
}

#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// In-process transports ship no worker-side telemetry: the sim run's
/// Chrome export must carry zero worker-process rows, so the export
/// stays byte-identical to its pre-telemetry shape (the worker rows
/// are purely additive, net-transport-only).
#[test]
fn sim_trace_has_no_worker_process_rows() {
    let (_, rec) = traced(1, 1, 20, 42);
    assert!(rec.worker_spans().is_empty(), "sim transport must not synthesize worker spans");
    assert!(rec.links().is_empty(), "sim transport must not report link stats");
    let trace = rec.chrome_trace();
    assert!(!trace.contains("(remote)"), "no worker-process metadata rows");
    assert!(!trace.contains("worker_compute"), "no nested remote compute slices");
    assert!(
        !rec.prometheus_live().contains("worker=\""),
        "live scrape degrades to the fixed families without net links"
    );
}

/// The streaming sink (`--events`) must see exactly the lines the
/// in-memory exporter reports, as they happen.
#[test]
fn events_sink_streams_the_same_lines() {
    let buf = SharedBuf::default();
    let rec = Recorder::new();
    rec.set_events_sink(Box::new(buf.clone()));
    let mut spec = RunSpec::new(8, 2, PolicyKind::Deterministic)
        .attack(AttackKind::SignFlip, 1.0, 2.0)
        .steps(15)
        .noise(0.05)
        .transport(TransportKind::Sim)
        .recorder(rec.clone());
    spec.byzantine = vec![3, 7];
    spec.run_linreg().expect("traced run");
    rec.close_events_sink();
    let streamed = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
    assert_eq!(streamed, rec.events_jsonl());
}
