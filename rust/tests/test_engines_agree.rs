//! Integration: the XLA engine (PJRT executing the AOT Pallas/JAX
//! artifacts) and the native Rust engine compute the same gradients,
//! losses, and SGD trajectories.
//!
//! Requires `artifacts/` (run `make artifacts`); each test is skipped
//! with a notice when the directory is missing so `cargo test` still
//! passes in a fresh checkout.

use std::sync::Arc;

use r3bft::data::{Batch, Dataset, LinRegDataset};
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine, XlaEngine};
use r3bft::linalg;
use r3bft::runtime::Runtime;
use r3bft::util::rng::Pcg64;

fn runtime() -> Option<Arc<Runtime>> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Runtime::cpu("artifacts").expect("runtime")))
}

#[test]
fn linreg_grad_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = ModelSpec::LinReg { d: 64, batch: 256 };
    let xla = XlaEngine::new(rt, spec.clone()).expect("xla engine");
    let native = NativeEngine::new(spec.clone());

    let ds = LinRegDataset::generate(256, 64, 0.1, 17);
    let batch = ds.batch(&(0..256).collect::<Vec<_>>());
    let theta = spec.init_theta(3);

    let a = xla.grad(&theta, &batch).expect("xla grad");
    let b = native.grad(&theta, &batch).expect("native grad");
    assert_eq!(a.grad.len(), 64);
    let rel = linalg::dist2(&a.grad, &b.grad) / linalg::norm2(&b.grad).max(1e-9);
    assert!(rel < 1e-4, "grad rel diff {rel}");
    assert!(
        (a.loss - b.loss).abs() < 1e-3 * (1.0 + b.loss.abs()),
        "loss {} vs {}",
        a.loss,
        b.loss
    );
}

#[test]
fn mlp_grad_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = ModelSpec::Mlp { in_dim: 32, hidden: 64, classes: 4, batch: 128 };
    let xla = XlaEngine::new(rt, spec.clone()).expect("xla engine");
    let native = NativeEngine::new(spec.clone());

    use r3bft::data::BlobsDataset;
    let ds = BlobsDataset::generate(128, 32, 4, 4.0, 23);
    let batch = ds.batch(&(0..128).collect::<Vec<_>>());
    let theta = spec.init_theta(5);

    let a = xla.grad(&theta, &batch).expect("xla grad");
    let b = native.grad(&theta, &batch).expect("native grad");
    let rel = linalg::dist2(&a.grad, &b.grad) / linalg::norm2(&b.grad).max(1e-9);
    assert!(rel < 1e-3, "grad rel diff {rel}");
    assert!((a.loss - b.loss).abs() < 1e-3 * (1.0 + b.loss.abs()));
}

#[test]
fn sgd_update_artifact_matches_axpy() {
    let Some(rt) = runtime() else { return };
    let spec = ModelSpec::LinReg { d: 64, batch: 256 };
    let xla = XlaEngine::new(rt, spec).expect("xla engine");

    let mut rng = Pcg64::seeded(7);
    let theta0 = rng.gauss_vec(64);
    let grad = rng.gauss_vec(64);

    let mut xla_theta = theta0.clone();
    xla.sgd_step(&mut xla_theta, &grad, 0.05).expect("xla step");

    let mut host_theta = theta0;
    linalg::axpy(-0.05, &grad, &mut host_theta);
    assert!(linalg::linf(&xla_theta, &host_theta) < 1e-6);
}

#[test]
fn transformer_grad_runs_and_decreases_loss() {
    let Some(rt) = runtime() else { return };
    let spec = ModelSpec::Transformer { param_dim: 136_512, batch: 8, seq_len: 65 };
    let xla = XlaEngine::new(rt, spec).expect("xla engine");

    use r3bft::data::Corpus;
    let corpus = Corpus::synthetic(4096, 65, 3);
    let ids: Vec<usize> = (0..8).map(|i| i * 37).collect();
    let batch = corpus.batch(&ids);

    let mut theta = r3bft::grad::models::init_transformer_tiny(1);
    let first = xla.grad(&theta, &batch).expect("tfm grad");
    // uniform-random init => loss near ln(256) ≈ 5.55
    assert!(first.loss > 3.0 && first.loss < 8.0, "init loss {}", first.loss);

    let mut loss = first.loss;
    let mut g = first.grad;
    for _ in 0..5 {
        xla.sgd_step(&mut theta, &g, 0.05).expect("step");
        let out = xla.grad(&theta, &batch).expect("grad");
        loss = out.loss;
        g = out.grad;
    }
    assert!(loss < first.loss, "loss did not decrease: {} -> {loss}", first.loss);
}

#[test]
fn xla_engine_rejects_wrong_batch_size() {
    let Some(rt) = runtime() else { return };
    let spec = ModelSpec::LinReg { d: 64, batch: 256 };
    let xla = XlaEngine::new(rt, spec).expect("xla engine");
    let bad = Batch::LinReg { x: vec![0.0; 10 * 64], y: vec![0.0; 10], b: 10, d: 64 };
    let err = xla.grad(&vec![0.0; 64], &bad).unwrap_err();
    assert!(err.to_string().contains("batch"), "unexpected error: {err}");
}
