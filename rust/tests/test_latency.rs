//! Latency-aware selective auditing: suspicion bit-identity across
//! transports at zero latency, straggler profiling through the full
//! training loop, suspicion decay under time-varying stragglers, the
//! metrics surface (suspicion CSV column, top suspect), and the
//! headline claim — `latency-selective` identifies a
//! slow-and-Byzantine worker with strictly fewer full-audit rounds
//! than `Bernoulli(q)` at equal q budget.

use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, GatherPolicy, PolicyKind,
    TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::{LatencyModel, SimConfig, StragglerModel, TrainOutcome};
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};

#[allow(clippy::too_many_arguments)]
fn run(
    n: usize,
    f: usize,
    byz: Vec<usize>,
    policy: PolicyKind,
    attack: AttackConfig,
    steps: usize,
    seed: u64,
    transport: &str,
    sim: SimConfig,
) -> TrainOutcome {
    let mut cluster = ClusterConfig::new(n, f, seed);
    cluster.byzantine_ids = byz;
    cluster.transport = transport.into();
    cluster.gather = GatherPolicy::All;
    let cfg = ExperimentConfig {
        name: "latency-test".into(),
        cluster,
        policy,
        attack,
        adversary: None,
        train: TrainConfig { steps, lr: 0.5, ..Default::default() },
    };
    let d = 8usize;
    let chunk = 4usize;
    let ds = Arc::new(LinRegDataset::generate(1024, d, 0.0, seed));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(seed);
    let opts = MasterOptions { sim, ..Default::default() };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    master.run().expect("train")
}

/// The acceptance contract: at zero latency the per-worker suspicion
/// updates are **bit-identical** across the threaded and simulated
/// transports. The latency anomaly quantizes to exactly 0 on both
/// (one shared arrival instant under sim; sub-millisecond scheduling
/// jitter under threaded), so suspicion reduces to the reliability
/// deficit, which evolves on the deterministic protocol RNG.
#[test]
fn suspicion_updates_bit_identical_across_transports_at_zero_latency() {
    let byz = vec![1usize, 4];
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 0.7, magnitude: 2.0 };
    let policy = PolicyKind::LatencySelective { q_base: 0.4 };
    let threaded = run(
        8,
        2,
        byz.clone(),
        policy.clone(),
        attack.clone(),
        60,
        19,
        "threaded",
        SimConfig::default(),
    );
    let sim = run(8, 2, byz, policy, attack, 60, 19, "sim", SimConfig::default());
    let a = threaded.events.suspicion_updates();
    let b = sim.events.suspicion_updates();
    assert!(!a.is_empty(), "no suspicion updates: nothing was compared");
    assert_eq!(a, b, "suspicion updates diverged across transports");
    assert_eq!(threaded.theta, sim.theta, "theta diverged");
    assert_eq!(threaded.eliminated, sim.eliminated);
    // the per-iteration suspicion column agrees too (bitwise)
    for (ra, rb) in threaded
        .metrics
        .iterations
        .iter()
        .zip(sim.metrics.iterations.iter())
    {
        assert_eq!(ra.suspicion, rb.suspicion, "iter {}", ra.iter);
        assert_eq!(ra.audited_chunks, rb.audited_chunks, "iter {}", ra.iter);
    }
}

/// An honest-but-slow worker becomes the top suspect — audited more,
/// but never eliminated (slow is not lying: its audits come back
/// unanimous), and its chunks' audit replicas land on trusted workers.
#[test]
fn persistent_straggler_becomes_top_suspect_but_is_never_eliminated() {
    let n = 8usize;
    let straggler = n - 1;
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(straggler, 50.0)],
        ..Default::default()
    };
    let out = run(
        n,
        1,
        vec![],
        PolicyKind::LatencySelective { q_base: 0.2 },
        AttackConfig::default(),
        60,
        31,
        "sim",
        sim,
    );
    // the straggler's suspicion was reported and ends high
    let last = out.events.last_suspicion(straggler).expect("no suspicion event");
    assert!(last >= 0.4, "straggler suspicion {last}");
    assert_eq!(out.metrics.top_suspect().map(|(w, _)| w), Some(straggler));
    // every other worker stays clean
    for w in 0..straggler {
        assert_eq!(out.events.last_suspicion(w), None, "worker {w} flagged");
    }
    // suspicion lands in the CSV column
    let csv = out.metrics.to_csv();
    assert!(csv.lines().next().unwrap().ends_with("audited_chunks,suspicion"));
    assert!(
        csv.lines().last().unwrap().contains(&format!("{straggler}:")),
        "suspicion column missing the straggler"
    );
    // slow != Byzantine: audited repeatedly, eliminated never
    assert!(out.events.audits() > 0);
    assert!(out.eliminated.is_empty());
    assert!(out.crashed.is_empty());
    assert_eq!(out.events.detections(), 0, "an honest straggler never trips detection");
}

/// Time-varying stragglers (the adversarial case for an EWMA): the
/// suspicion must rise during a slow burst and decay back once the
/// worker recovers — a burst is not a life sentence.
#[test]
fn time_varying_straggler_suspicion_decays_after_the_burst() {
    let n = 8usize;
    let w = n - 1; // bursts at iters where (iter + 7) % 40 < 10
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(w, 50.0)],
        straggler_model: StragglerModel::TimeVarying { period: 40, duty: 10 },
        ..Default::default()
    };
    // 72 steps: the mini-burst at iters 0..2 (sample-gated, no event),
    // the main burst at 33..42, and its decay — ending before the next
    // burst window opens at iter 73
    let out = run(
        n,
        1,
        vec![],
        PolicyKind::LatencySelective { q_base: 0.2 },
        AttackConfig::default(),
        72,
        37,
        "sim",
        sim,
    );
    let updates: Vec<(u64, f64)> = out
        .events
        .suspicion_updates()
        .into_iter()
        .filter(|&(_, worker, _)| worker == w)
        .map(|(iter, _, s)| (iter, s))
        .collect();
    assert!(!updates.is_empty(), "burst never registered");
    let peak = updates.iter().map(|&(_, s)| s).fold(0.0f64, f64::max);
    assert!(peak >= 0.3, "burst peak suspicion {peak}");
    let (last_iter, last) = *updates.last().unwrap();
    assert!(last < 0.1, "suspicion failed to decay after the burst: {last}");
    assert!(last_iter > 42, "decay must postdate the main burst (iters 33..42)");
    assert!(out.eliminated.is_empty());
}

/// The headline claim, at test scale (the full sweep writes
/// `BENCH_latency_audit.json` from `bench_transport`): one worker is
/// both a 50x straggler and an intermittent sign-flipper. At equal q
/// budget, `latency-selective` concentrates per-worker audits on the
/// suspect and identifies it with strictly fewer *full-audit* rounds
/// than `Bernoulli(q)` — which can only catch it by paying for a full
/// n-chunk audit on a round where the worker happens to tamper.
#[test]
fn latency_selective_identifies_slow_byzantine_with_fewer_full_audits() {
    let n = 64usize;
    let villain = n - 1;
    let steps = 400usize;
    let q = 0.2f64;
    let attack = AttackConfig { kind: AttackKind::SignFlip, p: 0.3, magnitude: 2.0 };
    let sim = SimConfig {
        latency: LatencyModel::Fixed { us: 100 },
        stragglers: vec![(villain, 50.0)],
        ..Default::default()
    };
    let count_full = |out: &TrainOutcome| {
        let horizon = out
            .events
            .identification_time(villain)
            .map(|t| t as usize + 1)
            .unwrap_or(steps);
        out.metrics.iterations[..horizon]
            .iter()
            .filter(|r| r.audited && r.audited_chunks >= n)
            .count()
    };
    let bernoulli = run(
        n,
        1,
        vec![villain],
        PolicyKind::Bernoulli { q },
        attack.clone(),
        steps,
        42,
        "sim",
        sim.clone(),
    );
    let latency = run(
        n,
        1,
        vec![villain],
        PolicyKind::LatencySelective { q_base: q },
        attack,
        steps,
        42,
        "sim",
        sim,
    );
    assert_eq!(latency.eliminated, vec![villain], "latency-selective missed the liar");
    let (full_b, full_l) = (count_full(&bernoulli), count_full(&latency));
    assert!(
        full_l < full_b,
        "latency-selective used {full_l} full audits, bernoulli {full_b}"
    );
    // the targeted policy never needs a full audit at all: every audit
    // it pays for is a per-worker subset
    assert_eq!(full_l, 0);
    // the timing/reliability signal surfaced along the way
    assert!(!latency.events.suspicion_updates().is_empty(), "no suspicion was reported");
}
