//! Property-based tests over the coordinator invariants (DESIGN.md
//! §Invariants), using the in-tree quickcheck harness (proptest is
//! unavailable offline). Each property runs across many random
//! cluster shapes, attacks, policies, and seeds; failures replay via
//! R3BFT_PROP_SEED=<name>:<seed>.

use r3bft::config::{AttackKind, PolicyKind};
use r3bft::coordinator::assignment::Assignment;
use r3bft::coordinator::codes::{check_copies, CheckOutcome, SymbolCopy};
use r3bft::coordinator::identify::majority_vote;
use r3bft::coordinator::analysis;
use r3bft::experiments::common::RunSpec;
use r3bft::util::quickcheck::forall;
use r3bft::util::rng::Pcg64;
use r3bft::{linalg, prop_assert, prop_assert_close};

/// Invariant 5: assignment validity over random shapes.
#[test]
fn prop_assignment_validity() {
    forall("assignment validity", 300, |g| {
        let n = g.usize_in(1, 40);
        let r = g.usize_in(1, n);
        let cs = g.usize_in(1, 8);
        let active: Vec<usize> = g.distinct(64, n);
        let ids: Vec<usize> = (0..n * cs).collect();
        let a = Assignment::new(&ids, &active, r);
        a.validate().map_err(|e| e)?;
        // every chunk has exactly r owners; every worker owns exactly r chunks
        for owners in &a.owners {
            prop_assert!(owners.len() == r, "chunk owners {} != r {r}", owners.len());
        }
        for &w in &active {
            prop_assert!(a.chunks_of(w).len() == r, "worker {w} chunk count");
        }
        // chunks partition the ids
        let mut all: Vec<usize> = a.chunks.iter().flatten().copied().collect();
        all.sort_unstable();
        prop_assert!(all == ids, "chunks do not partition the data");
        Ok(())
    });
}

/// Invariant 5 (reactive part): extension adds distinct new owners only.
#[test]
fn prop_assignment_extension() {
    forall("assignment extension", 200, |g| {
        let n = g.usize_in(3, 32);
        let r = g.usize_in(1, n - 1);
        let active: Vec<usize> = (0..n).collect();
        let ids: Vec<usize> = (0..n * 2).collect();
        let mut a = Assignment::new(&ids, &active, r);
        let c = g.usize_in(0, a.nchunks() - 1);
        let extra = g.usize_in(1, n - r);
        let mut rng = Pcg64::seeded(g.case_seed ^ 0x55);
        let added = a.extend(c, extra, &mut rng);
        prop_assert!(added.len() == extra, "extend returned wrong count");
        a.validate().map_err(|e| e)?;
        prop_assert!(a.owners[c].len() == r + extra, "owner count after extend");
        Ok(())
    });
}

/// Satellite invariant (sharded PR): for random (n, f, r) within
/// bounds and a random eliminated subset, the proactive assignment
/// gives every chunk exactly r *distinct* owners, never assigns an
/// eliminated worker (neither proactively nor via reactive extension),
/// and covers every sampled data point exactly once.
#[test]
fn prop_assignment_excludes_eliminated_and_covers_all() {
    forall("assignment excludes eliminated", 300, |g| {
        let n = g.usize_in(3, 48);
        let f = g.usize_in(0, (n - 1) / 2); // 2f < n
        let n_elim = g.usize_in(0, f);
        let eliminated: Vec<usize> = g.distinct(n, n_elim);
        let active: Vec<usize> = (0..n).filter(|w| !eliminated.contains(w)).collect();
        let nact = active.len();
        let r = g.usize_in(1, nact);
        let cs = g.usize_in(1, 6);
        let ids: Vec<usize> = (1000..1000 + nact * cs).collect();
        let mut a = Assignment::new(&ids, &active, r);
        a.validate().map_err(|e| e)?;
        for (c, owners) in a.owners.iter().enumerate() {
            prop_assert!(owners.len() == r, "chunk {c}: {} owners != r {r}", owners.len());
            let mut u = owners.clone();
            u.sort_unstable();
            u.dedup();
            prop_assert!(u.len() == r, "chunk {c} has duplicate owners");
            for w in owners {
                prop_assert!(!eliminated.contains(w), "eliminated worker {w} owns chunk {c}");
            }
        }
        // coverage is total: the chunks partition the sampled ids
        let mut all: Vec<usize> = a.chunks.iter().flatten().copied().collect();
        all.sort_unstable();
        prop_assert!(all == ids, "chunks do not cover the sampled points exactly once");
        // reactive extension also never resurrects an eliminated worker
        if r < nact {
            let c = g.usize_in(0, a.nchunks() - 1);
            let extra = g.usize_in(1, nact - r);
            let mut rng = Pcg64::seeded(g.case_seed ^ 0xe11);
            let added = a.extend(c, extra, &mut rng);
            prop_assert!(added.len() == extra, "extend count");
            for w in &added {
                prop_assert!(!eliminated.contains(w), "extend chose eliminated worker {w}");
            }
            a.validate().map_err(|e| e)?;
        }
        Ok(())
    });
}

/// Invariant 6: detection fires iff some copy is perturbed.
#[test]
fn prop_detection_iff_perturbed() {
    forall("detection iff perturbed", 300, |g| {
        let d = g.usize_in(1, 64);
        let r = g.usize_in(2, 6);
        let grad = g.vec_f32(d);
        let mut copies: Vec<SymbolCopy> = (0..r)
            .map(|w| SymbolCopy { worker: w, grad: grad.clone(), loss: 0.5, wire: None })
            .collect();
        prop_assert!(
            check_copies(&copies, 0.0) == CheckOutcome::Unanimous,
            "clean copies flagged"
        );
        // perturb one copy by the smallest representable amount
        let victim = g.usize_in(0, r - 1);
        let coord = g.usize_in(0, d - 1);
        let old = copies[victim].grad[coord];
        copies[victim].grad[coord] = f32::from_bits(old.to_bits() ^ 1);
        prop_assert!(
            check_copies(&copies, 0.0) == CheckOutcome::FaultDetected,
            "1-ulp perturbation missed"
        );
        Ok(())
    });
}

/// Majority vote: honest quorum always wins; exactly the liars are named.
#[test]
fn prop_majority_vote_soundness() {
    forall("majority vote soundness", 300, |g| {
        let f_t = g.usize_in(1, 4);
        let d = g.usize_in(1, 32);
        let truth = g.vec_f32(d);
        let n_copies = 2 * f_t + 1;
        let n_liars = g.usize_in(0, f_t);
        let liar_set: Vec<usize> = g.distinct(n_copies, n_liars);
        let copies: Vec<SymbolCopy> = (0..n_copies)
            .map(|w| {
                let mut grad = truth.clone();
                if liar_set.contains(&w) {
                    // arbitrary corruption, possibly colluding (same value)
                    let colluding = w % 2 == 0;
                    for (i, v) in grad.iter_mut().enumerate() {
                        *v = if colluding { 9.0 + i as f32 } else { -3.0 * (*v) + 1.0 };
                    }
                }
                SymbolCopy { worker: w, grad, loss: 1.0, wire: None }
            })
            .collect();
        let vote = majority_vote(&copies, f_t).ok_or("no quorum")?;
        prop_assert!(vote.grad == truth, "majority returned wrong value");
        let mut liars = vote.liars.clone();
        liars.sort_unstable();
        let mut expect = liar_set.clone();
        expect.sort_unstable();
        // a liar whose corruption happens to equal the truth is impossible
        // here (corruption always changes some coordinate unless truth has
        // special fixed-point values; filter those out)
        let mut really_lied: Vec<usize> = expect
            .iter()
            .copied()
            .filter(|&w| copies[w].grad != truth)
            .collect();
        really_lied.sort_unstable();
        prop_assert!(liars == really_lied, "liars {liars:?} != expected {really_lied:?}");
        Ok(())
    });
}

/// Invariant 7: closed-form q* equals the numeric argmin everywhere.
#[test]
fn prop_qstar_closed_form() {
    forall("qstar closed form", 200, |g| {
        let f_t = g.usize_in(0, 10);
        let p = g.f64_in(0.0, 1.0);
        let lambda = g.f64_in(0.0, 1.0);
        let closed = analysis::eq4_qstar(lambda, p, f_t);
        let numeric = analysis::eq4_qstar_numeric(lambda, p, f_t, 50_000);
        prop_assert_close!(closed, numeric, 2e-4);
        prop_assert!((0.0..=1.0).contains(&closed), "q* out of range: {closed}");
        Ok(())
    });
}

/// Invariants 1-4 on full protocol runs: exact recovery, identification
/// soundness, efficiency accounting — across random clusters/attacks.
#[test]
fn prop_protocol_invariants() {
    forall("protocol invariants", 25, |g| {
        let f = g.usize_in(1, 3);
        let n = g.usize_in(2 * f + 1, 2 * f + 6);
        let n_byz = g.usize_in(0, f);
        let byz: Vec<usize> = g.distinct(n, n_byz);
        let attacks = AttackKind::ALL;
        let attack = *g.choose(&attacks);
        let p = g.f64_in(0.2, 1.0);
        let policy = match g.usize_in(0, 2) {
            0 => PolicyKind::Deterministic,
            1 => PolicyKind::Bernoulli { q: g.f64_in(0.1, 0.9) },
            _ => PolicyKind::Adaptive { p_assumed: 0.5 },
        };
        let mut spec = RunSpec::new(n, f, policy);
        spec.byzantine = byz.clone();
        let (out, w_star) = spec
            .attack(attack, p, 2.0)
            .steps(120)
            .seed(g.case_seed)
            .run_linreg()
            .map_err(|e| format!("{e:#}"))?;

        // Invariant 2 (soundness): only truly-Byzantine workers eliminated
        for w in &out.eliminated {
            prop_assert!(byz.contains(w), "honest worker {w} eliminated (byz={byz:?})");
        }
        // Invariant 4: accounting
        for r in &out.metrics.iterations {
            prop_assert!(
                r.gradients_used <= r.gradients_computed,
                "used > computed at iter {}",
                r.iter
            );
        }
        // Invariant 1 (exactness): if all byz identified (or none exist),
        // training must converge to the planted optimum
        if out.eliminated.len() == byz.len() {
            let dist = linalg::dist2(&out.theta, &w_star);
            prop_assert!(
                dist < 0.5,
                "convergence failed after full identification: dist={dist} \
                 (n={n} f={f} byz={byz:?} attack={attack:?})"
            );
        }
        Ok(())
    });
}

/// Invariant 3 (completeness): under deterministic auditing, a worker
/// tampering with p = 1 is identified in the very first iteration.
#[test]
fn prop_immediate_identification_when_deterministic() {
    forall("immediate identification", 25, |g| {
        let f = g.usize_in(1, 3);
        let n = 2 * f + 1 + g.usize_in(0, 4);
        let byz: Vec<usize> = g.distinct(n, f);
        let mut spec = RunSpec::new(n, f, PolicyKind::Deterministic);
        spec.byzantine = byz.clone();
        let attacks = [AttackKind::SignFlip, AttackKind::Noise, AttackKind::Constant];
        let (out, _) = spec
            .attack(*g.choose(&attacks), 1.0, 3.0)
            .steps(3)
            .seed(g.case_seed)
            .run_linreg()
            .map_err(|e| format!("{e:#}"))?;
        for &w in &byz {
            let t = out.events.identification_time(w);
            prop_assert!(
                t == Some(0),
                "worker {w} identified at {t:?}, expected iteration 0 (byz={byz:?})"
            );
        }
        Ok(())
    });
}

/// Aggregation exactness: in audited iterations the used gradient equals
/// the honest chunk means bit-for-bit (replication code exact recovery).
#[test]
fn prop_filters_never_exact_but_schemes_are() {
    forall("filters approximate vs schemes exact", 50, |g| {
        let d = g.usize_in(4, 64);
        let n = g.usize_in(7, 15);
        let f = g.usize_in(1, (n - 1) / 2.min(3));
        let truth = g.vec_f32(d);
        let mut grads: Vec<Vec<f32>> = (0..n)
            .map(|_| truth.iter().map(|&v| v + 0.01 * g.f32_in(-1.0, 1.0)).collect())
            .collect();
        for gr in grads.iter_mut().take(f) {
            for v in gr.iter_mut() {
                *v += g.f32_in(5.0, 50.0);
            }
        }
        let honest: Vec<&[f32]> = grads[f..].iter().map(|v| v.as_slice()).collect();
        let honest_mean = linalg::mean_of(&honest);
        for filt in r3bft::baselines::filters::all_filters() {
            let agg = filt.aggregate(&grads, f);
            let err = linalg::dist2(&agg, &honest_mean);
            prop_assert!(
                err.is_finite(),
                "{} produced non-finite aggregate",
                filt.name()
            );
        }
        Ok(())
    });
}
