//! API-compatible stub for the `xla` PJRT bindings.
//!
//! The build environment has neither the xla-rs crate nor a PJRT
//! shared library, so this stub keeps the `runtime` module compiling
//! while making the unavailability explicit at *runtime*:
//! [`PjRtClient::cpu`] — the single entry point every XLA code path
//! goes through — returns an error, so `r3bft --engine xla` fails with
//! a clear message and everything else (native engine, tests, benches)
//! runs normally. Swap this path dependency for the real crate to
//! enable the PJRT backend; no r3bft source changes are needed.

use std::fmt;

/// Error type matching the real crate's role in signatures.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "XLA/PJRT backend unavailable: built against the in-tree xla stub \
         (vendor/xla). Use --engine native, or build with the real xla crate."
            .into(),
    )
}

type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (stub: carries no data).
pub struct Literal(());

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle (stub: cannot be constructed).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"), "{err}");
    }
}
