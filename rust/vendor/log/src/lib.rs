//! Offline substitute for the `log` facade crate.
//!
//! Provides the [`Log`] trait, [`Level`] / [`LevelFilter`], the global
//! logger registry, and the `error!` .. `trace!` macros — enough for
//! r3bft's `util::logger` backend and call sites.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logging levels, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Level filter: like [`Level`] plus `Off`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus preformatted arguments.
#[derive(Clone, Copy, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// Used by the macro expansions; not part of the real crate's
    /// public API surface but harmless to expose.
    pub fn new(level: Level, target: &'a str, args: fmt::Arguments<'a>) -> Record<'a> {
        Record { metadata: Metadata { level, target }, args }
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record::new(level, target, args);
            if logger.enabled(record.metadata()) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Error, ::std::module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Warn, ::std::module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Info, ::std::module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Debug, ::std::module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Trace, ::std::module_path!(), ::std::format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            assert!(!record.target().is_empty());
            let _ = format!("{}", record.args());
            HITS.fetch_add(1, Ordering::SeqCst);
        }
        fn flush(&self) {}
    }

    static COUNTER: Counter = Counter;

    #[test]
    fn levels_and_dispatch() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));

        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        debug!("filtered {}", 2); // below max level: not delivered
        assert_eq!(HITS.load(Ordering::SeqCst), 1);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
