//! Offline substitute for the `anyhow` crate.
//!
//! Implements the slice of anyhow's API that r3bft uses: a boxed-free
//! string-chain [`Error`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, the [`Context`] extension trait for `Result` and `Option`,
//! and `From<E: std::error::Error>` so `?` works on std error types.
//!
//! Formatting matches anyhow's conventions: `{}` shows the outermost
//! message, `{:#}` shows the whole context chain separated by `: `.

use std::fmt;

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error value carrying a chain of context messages, outermost
/// first (index 0 is what `{}` displays; the last entry is the root
/// cause).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "Condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/8251").with_context(|| "reading config")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let err = fails_io().unwrap_err();
        assert_eq!(err.to_string(), "reading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(full.len() > "reading config: ".len());
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let err = x.context("missing").unwrap_err();
        assert_eq!(err.to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "flag was {}", ok);
            Ok(1)
        }
        fn g() -> Result<u32> {
            bail!("always fails: {}", 42);
        }
        fn h(ok: bool) -> Result<u32> {
            ensure!(ok);
            Ok(2)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(g().unwrap_err().to_string(), "always fails: 42");
        assert!(h(false).unwrap_err().to_string().contains("Condition failed"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
