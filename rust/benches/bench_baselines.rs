//! Bench E10: gradient-filter residuals + filter-aggregation speed.

use r3bft::baselines::filters::all_filters;
use r3bft::util::bench::{black_box, run, BenchOpts};
use r3bft::util::rng::Pcg64;

fn main() {
    let fast = !std::env::args().any(|a| a == "--full");
    r3bft::experiments::run("e10", fast).unwrap();

    // aggregation-speed microbench: filters vs plain mean, n=25, d=4096
    println!("\n#### filter aggregation speed (n=25 workers, d=4096)");
    let mut rng = Pcg64::seeded(1);
    let grads: Vec<Vec<f32>> = (0..25).map(|_| rng.gauss_vec(4096)).collect();
    let opts = BenchOpts::default();
    let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    run("mean (exact schemes' cost)", opts, || {
        black_box(r3bft::linalg::mean_of(black_box(&refs)));
    });
    for filt in all_filters() {
        run(filt.name(), opts, || {
            black_box(filt.aggregate(black_box(&grads), 4));
        });
    }
}
