//! Round-throughput benchmark for the pipelined driver and the
//! bit-packed wire formats, written to `BENCH_throughput.json`.
//!
//! Two sweeps, both in deterministic virtual time (SimTransport, fixed
//! per-message latency), fault-free with an always-audit q = 1 budget
//! so every round costs a proactive wave *plus* a detection wave:
//!
//! * **pipeline** — n ∈ {64, 256, 1024}, depth 1 vs 2. At depth 1 a
//!   round serializes both waves (2 L of latency); at depth 2 the next
//!   round's proactive wave overlaps the audit, so steady-state
//!   exclusive round time drops to one wave (L) — a 2.0× round-time
//!   speedup, exact in virtual time.
//! * **packing** — dense vs signSGD vs top-k wire bytes per round at
//!   d = 1024 (sign packs 1 bit/coordinate + a 4-byte scale: ≥ 16×
//!   fewer bytes on the wire than 4-byte floats).

use std::collections::BTreeMap;
use std::sync::Arc;

use r3bft::config::{
    AttackConfig, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig,
};
use r3bft::coordinator::compress::{Compressor, SignSgd, TopK};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::{LatencyModel, SimConfig, TrainOutcome};
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};
use r3bft::util::bench::Table;
use r3bft::util::json::Json;

const LATENCY_US: u64 = 200;

fn run_once(
    n: usize,
    d: usize,
    chunk: usize,
    pipeline: usize,
    steps: usize,
    compressor: Option<Arc<dyn Compressor>>,
) -> TrainOutcome {
    let mut cluster = ClusterConfig::new(n, 1, 42);
    cluster.byzantine_ids = vec![];
    cluster.transport = "sim".into();
    cluster.pipeline = pipeline;
    let cfg = ExperimentConfig {
        name: format!("bench-throughput-{n}x{pipeline}"),
        cluster,
        policy: PolicyKind::Bernoulli { q: 1.0 },
        attack: AttackConfig::default(),
        adversary: None,
        train: TrainConfig { steps, lr: 0.1, ..Default::default() },
    };
    let ds = Arc::new(LinRegDataset::generate(8192, d, 0.0, 42));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(42);
    let opts = MasterOptions {
        compressor,
        sim: SimConfig { latency: LatencyModel::Fixed { us: LATENCY_US }, ..Default::default() },
        ..Default::default()
    };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    master.run().expect("train")
}

/// Steady-state mean over rounds ≥ 1 (round 0 fills the pipeline and
/// always costs the full two waves at any depth).
fn steady<F: Fn(&r3bft::coordinator::metrics::IterationRecord) -> f64>(
    out: &TrainOutcome,
    f: F,
) -> f64 {
    let rows = &out.metrics.iterations[1..];
    rows.iter().map(f).sum::<f64>() / rows.len().max(1) as f64
}

fn main() {
    let steps = 30usize;
    let d_pipe = 16usize;
    let chunk = 4usize;

    println!("#### pipelined rounds: exclusive round time, depth 2 vs 1 (sim, q=1, L={LATENCY_US}us)");
    let mut table = Table::new(&["n", "depth", "round us", "ns/element", "speedup"]);
    let mut pipe_rows: Vec<Json> = Vec::new();
    let mut speedup_1024 = 0.0f64;
    for &n in &[64usize, 256, 1024] {
        let base = run_once(n, d_pipe, chunk, 1, steps, None);
        let piped = run_once(n, d_pipe, chunk, 2, steps, None);
        // trajectories must agree bitwise before timings mean anything
        assert_eq!(base.theta, piped.theta, "n={n}: pipelined trajectory diverged");
        let elements = (n * d_pipe) as f64; // aggregated grad elements per round
        for (depth, out) in [(1usize, &base), (2, &piped)] {
            let round_ns = steady(out, |r| r.round_ns as f64);
            let speedup = steady(&base, |r| r.round_ns as f64) / round_ns;
            table.row(&[
                n.to_string(),
                depth.to_string(),
                format!("{:.1}", round_ns / 1e3),
                format!("{:.1}", round_ns / elements),
                format!("{speedup:.2}x"),
            ]);
            let mut obj = BTreeMap::new();
            obj.insert("n".to_string(), Json::Num(n as f64));
            obj.insert("pipeline_depth".to_string(), Json::Num(depth as f64));
            obj.insert("round_ns".to_string(), Json::Num(round_ns));
            obj.insert("ns_per_element".to_string(), Json::Num(round_ns / elements));
            obj.insert(
                "bytes_round".to_string(),
                Json::Num(steady(out, |r| r.bytes_round as f64)),
            );
            pipe_rows.push(Json::Obj(obj));
            if n == 1024 && depth == 2 {
                speedup_1024 = speedup;
            }
        }
    }
    table.print("pipeline sweep (steady-state mean, round 0 excluded)");
    assert!(
        speedup_1024 >= 1.99,
        "depth-2 round-time speedup at n=1024 must be >= 2x, got {speedup_1024:.3}x"
    );

    println!("\n#### bit-packed wire symbols: bytes/round at d = 1024 (n = 64)");
    let d_pack = 1024usize;
    let n_pack = 64usize;
    let packs: Vec<(&str, Option<Arc<dyn Compressor>>)> = vec![
        ("dense (no wire)", None),
        ("signSGD", Some(Arc::new(SignSgd))),
        ("top-32", Some(Arc::new(TopK { k: 32 }))),
    ];
    let mut ptable = Table::new(&["wire", "bytes/round", "vs dense"]);
    let mut pack_rows: Vec<Json> = Vec::new();
    let mut dense_bytes = 0.0f64;
    let mut sign_ratio = 0.0f64;
    for (name, comp) in packs {
        let out = run_once(n_pack, d_pack, chunk, 2, steps, comp);
        let bytes = steady(&out, |r| r.bytes_round as f64);
        if name.starts_with("dense") {
            dense_bytes = bytes;
        }
        let ratio = if bytes > 0.0 { dense_bytes / bytes } else { 0.0 };
        if name == "signSGD" {
            sign_ratio = ratio;
        }
        ptable.row(&[name.into(), format!("{bytes:.0}"), format!("{ratio:.1}x")]);
        let mut obj = BTreeMap::new();
        obj.insert("wire".to_string(), Json::Str(name.to_string()));
        obj.insert("bytes_round".to_string(), Json::Num(bytes));
        obj.insert("ratio_vs_dense".to_string(), Json::Num(ratio));
        pack_rows.push(Json::Obj(obj));
    }
    ptable.print("wire packing (pipelined depth 2, steady-state mean)");
    assert!(
        sign_ratio >= 16.0,
        "signSGD must cut bytes/round by >= 16x at d=1024, got {sign_ratio:.1}x"
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("round_throughput".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(format!(
            "linreg fault-free sim latency=fixed:{LATENCY_US}us q=1.0 f=1 steps={steps} \
             chunk={chunk} pipeline d={d_pipe} / packing d={d_pack} n={n_pack} seed=42"
        )),
    );
    doc.insert("pipeline".to_string(), Json::Arr(pipe_rows));
    doc.insert("packing".to_string(), Json::Arr(pack_rows));
    doc.insert("round_time_speedup_n1024_depth2".to_string(), Json::Num(speedup_1024));
    doc.insert("signsgd_bytes_ratio_d1024".to_string(), Json::Num(sign_ratio));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_throughput.json", &json) {
        Ok(()) => println!("\nwrote BENCH_throughput.json"),
        Err(e) => eprintln!("failed to write BENCH_throughput.json: {e}"),
    }
}
