//! Transport-layer dispatch overhead: SimTransport vs
//! ThreadedTransport across cluster sizes, the sharded
//! parameter-server sweep (n × K) written to `BENCH_shard.json`, the
//! quorum-gather straggler sweep written to `BENCH_quorum.json`
//! (virtual round time, All vs Quorum, one 50× straggler), and the
//! latency-aware selective-audit sweep written to
//! `BENCH_latency_audit.json` (one slow-and-Byzantine worker;
//! `latency-selective` vs `Bernoulli(q)` at equal q budget).
//!
//! The workload is deliberately tiny (linreg d = 4, chunk = 2) so the
//! numbers are dominated by per-iteration dispatch — assignment,
//! submit/poll, ingest, partial-aggregate fusion — not by gradient
//! math. The threaded transport is capped at n = 256 (one OS thread
//! per worker); the simulator sweeps to n = 1024 on a single thread,
//! which is the point of having it.

use std::collections::BTreeMap;
use std::sync::Arc;

use r3bft::config::{
    AttackConfig, AttackKind, ClusterConfig, ExperimentConfig, GatherPolicy, PolicyKind,
    TrainConfig,
};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::{LatencyModel, SimConfig};
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};
use r3bft::util::bench::{black_box, Table};
use r3bft::util::json::Json;

const THREADED_CAP: usize = 256;

fn run_once(n: usize, shards: usize, transport: &str, steps: usize) -> f64 {
    let d = 4usize;
    let chunk = 2usize;
    let mut cluster = ClusterConfig::new(n, 1, 42);
    cluster.byzantine_ids = vec![];
    cluster.f = 0;
    cluster.transport = transport.into();
    cluster.shards = shards;
    let cfg = ExperimentConfig {
        name: format!("bench-{transport}-{n}x{shards}"),
        cluster,
        policy: PolicyKind::None,
        attack: AttackConfig::default(),
        adversary: None,
        train: TrainConfig { steps, lr: 0.1, ..Default::default() },
    };
    let ds = Arc::new(LinRegDataset::generate(4096, d, 0.0, 42));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(42);
    let master =
        Master::new(cfg, MasterOptions::default(), engine, ds, theta0, chunk).expect("master");
    let t0 = std::time::Instant::now();
    let out = master.run().expect("run");
    let dt = t0.elapsed().as_secs_f64();
    black_box(out);
    dt / steps as f64
}

/// One straggler-scenario run: fixed 100µs base latency, one 50×
/// straggler (the last worker), fault-free, policy=none. Returns the
/// mean **virtual** round time in µs — the number a quorum gather is
/// supposed to cut from straggler-dominated (~5000µs) to
/// quorum-dominated (~100µs + one reassignment wave).
fn run_straggler(n: usize, gather: GatherPolicy, steps: usize) -> f64 {
    let d = 4usize;
    let chunk = 2usize;
    let mut cluster = ClusterConfig::new(n, 1, 42);
    cluster.byzantine_ids = vec![];
    cluster.f = 0;
    cluster.transport = "sim".into();
    cluster.gather = gather;
    let cfg = ExperimentConfig {
        name: format!("bench-straggler-{n}"),
        cluster,
        policy: PolicyKind::None,
        attack: AttackConfig::default(),
        adversary: None,
        train: TrainConfig { steps, lr: 0.1, ..Default::default() },
    };
    let opts = MasterOptions {
        sim: SimConfig {
            latency: LatencyModel::Fixed { us: 100 },
            stragglers: vec![(n - 1, 50.0)],
            ..Default::default()
        },
        ..Default::default()
    };
    let ds = Arc::new(LinRegDataset::generate(4096, d, 0.0, 42));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(42);
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    let out = master.run().expect("run");
    let us = out.metrics.mean_round_ns() / 1e3;
    black_box(out);
    us
}

/// One latency-audit run: worker n-1 is Byzantine (sign-flip with
/// tamper probability 0.3 — intermittent, so an audit only catches it
/// when it happens to lie) *and* a 50× straggler on 100 µs base
/// latency. Returns (identified-at iteration, full-audit rounds up to
/// and including identification, audited rounds in the same window,
/// average efficiency). All timing is deterministic virtual time.
fn run_latency_audit(
    n: usize,
    policy: PolicyKind,
    steps: usize,
) -> (Option<u64>, usize, usize, f64) {
    let d = 4usize;
    let chunk = 2usize;
    let mut cluster = ClusterConfig::new(n, 1, 42);
    cluster.byzantine_ids = vec![n - 1];
    cluster.transport = "sim".into();
    let cfg = ExperimentConfig {
        name: format!("bench-latency-audit-{n}"),
        cluster,
        policy,
        attack: AttackConfig { kind: AttackKind::SignFlip, p: 0.3, magnitude: 2.0 },
        adversary: None,
        train: TrainConfig { steps, lr: 0.1, ..Default::default() },
    };
    let opts = MasterOptions {
        sim: SimConfig {
            latency: LatencyModel::Fixed { us: 100 },
            stragglers: vec![(n - 1, 50.0)],
            ..Default::default()
        },
        ..Default::default()
    };
    let ds = Arc::new(LinRegDataset::generate(4096, d, 0.0, 42));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(42);
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    let out = master.run().expect("run");
    let identified_at = out.events.identification_time(n - 1);
    let horizon = identified_at.map(|t| t as usize + 1).unwrap_or(steps);
    // a full-audit round covered every chunk (n chunks while the
    // cluster is whole); selective policies audit per-worker subsets
    let full_audits = out.metrics.iterations[..horizon]
        .iter()
        .filter(|r| r.audited && r.audited_chunks >= n)
        .count();
    let audit_rounds =
        out.metrics.iterations[..horizon].iter().filter(|r| r.audited).count();
    let eff = out.metrics.average_efficiency();
    black_box(out);
    (identified_at, full_audits, audit_rounds, eff)
}

fn main() {
    println!("#### transport dispatch overhead (linreg d=4, chunk=2, policy=none)");
    let mut table = Table::new(&["n", "sim us/iter", "threaded us/iter", "threaded/sim"]);
    for &n in &[8usize, 64, 256, 1024] {
        let steps = if n >= 1024 { 10 } else { 30 };
        let sim = run_once(n, 1, "sim", steps);
        let threaded = if n <= THREADED_CAP {
            Some(run_once(n, 1, "threaded", steps))
        } else {
            None // one OS thread per worker is not feasible at this n
        };
        table.row(&[
            n.to_string(),
            format!("{:.1}", sim * 1e6),
            threaded.map(|t| format!("{:.1}", t * 1e6)).unwrap_or_else(|| "-".into()),
            threaded.map(|t| format!("{:.2}x", t / sim)).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.print("transport sweep (per-iteration wall time)");
    println!(
        "\nnote: sim latency model is Zero here, so sim numbers are pure \
         dispatch + compute; threaded numbers add thread wake/IPC costs."
    );

    // ---- sharded dispatch sweep: n × K over the sim transport ----------
    println!("\n#### sharded parameter-server dispatch (sim transport)");
    let mut table = Table::new(&["n", "K=1 us/iter", "K=4 us/iter", "K=8 us/iter"]);
    let mut rows: Vec<Json> = Vec::new();
    for &n in &[64usize, 256, 1024] {
        let steps = if n >= 1024 { 10 } else { 30 };
        let mut cells = vec![n.to_string()];
        for &k in &[1usize, 4, 8] {
            let us = run_once(n, k, "sim", steps) * 1e6;
            cells.push(format!("{us:.1}"));
            let mut obj = BTreeMap::new();
            obj.insert("n".to_string(), Json::Num(n as f64));
            obj.insert("shards".to_string(), Json::Num(k as f64));
            obj.insert("us_per_iter".to_string(), Json::Num(us));
            rows.push(Json::Obj(obj));
        }
        table.row(&cells);
    }
    table.print("sharded sweep (per-iteration wall time)");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("shard_dispatch".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str("linreg d=4 chunk=2 policy=none transport=sim".to_string()),
    );
    doc.insert("results".to_string(), Json::Arr(rows));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_shard.json", &json) {
        Ok(()) => println!("\nwrote BENCH_shard.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_shard.json: {e}"),
    }

    // ---- quorum-gather straggler sweep: All vs Quorum{n-1} -------------
    println!("\n#### quorum gather under one 50x straggler (sim, fixed 100us latency)");
    let mut table = Table::new(&["n", "all us/round", "quorum us/round", "speedup"]);
    let mut rows: Vec<Json> = Vec::new();
    for &n in &[64usize, 256, 1024] {
        let steps = if n >= 1024 { 5 } else { 10 };
        let all = run_straggler(n, GatherPolicy::All, steps);
        let quorum = run_straggler(n, GatherPolicy::Quorum { k: n - 1 }, steps);
        let speedup = all / quorum.max(1e-9);
        table.row(&[
            n.to_string(),
            format!("{all:.1}"),
            format!("{quorum:.1}"),
            format!("{speedup:.1}x"),
        ]);
        let mut obj = BTreeMap::new();
        obj.insert("n".to_string(), Json::Num(n as f64));
        obj.insert("all_us_per_round".to_string(), Json::Num(all));
        obj.insert("quorum_us_per_round".to_string(), Json::Num(quorum));
        obj.insert("speedup".to_string(), Json::Num(speedup));
        rows.push(Json::Obj(obj));
    }
    table.print("quorum sweep (virtual round time)");
    println!(
        "\nnote: round time is virtual (the simulator's clock): All waits for \
         the 5000us straggler every round; Quorum{{n-1}} proceeds at 100us and \
         pays one ~100us reassignment wave for the straggler's chunks."
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("quorum_gather".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(
            "linreg d=4 chunk=2 policy=none transport=sim latency=fixed:100us \
             stragglers=[(n-1,50x)] gather=all|quorum:n-1"
                .to_string(),
        ),
    );
    doc.insert("results".to_string(), Json::Arr(rows));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_quorum.json", &json) {
        Ok(()) => println!("\nwrote BENCH_quorum.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_quorum.json: {e}"),
    }

    // ---- latency-aware selective audit: one slow-and-Byzantine worker --
    println!(
        "\n#### latency-aware selective audit (sim, one 50x straggler that is \
         also Byzantine, sign-flip p=0.3, q budget 0.2)"
    );
    let mut table = Table::new(&[
        "n",
        "policy",
        "identified at",
        "full audits",
        "audit rounds",
        "efficiency",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let q = 0.2f64;
    let steps = 400usize;
    for &n in &[64usize, 256] {
        let policies = [
            ("bernoulli", PolicyKind::Bernoulli { q }),
            ("latency-selective", PolicyKind::LatencySelective { q_base: q }),
        ];
        let mut full_by_policy = Vec::new();
        for (name, policy) in policies {
            let (id_at, full, audits, eff) = run_latency_audit(n, policy, steps);
            full_by_policy.push(full);
            table.row(&[
                n.to_string(),
                name.to_string(),
                id_at.map(|t| t.to_string()).unwrap_or_else(|| "never".into()),
                full.to_string(),
                audits.to_string(),
                format!("{eff:.4}"),
            ]);
            let mut obj = BTreeMap::new();
            obj.insert("n".to_string(), Json::Num(n as f64));
            obj.insert("policy".to_string(), Json::Str(name.to_string()));
            obj.insert("q".to_string(), Json::Num(q));
            obj.insert(
                "identified_at".to_string(),
                id_at.map(|t| Json::Num(t as f64)).unwrap_or(Json::Null),
            );
            obj.insert("full_audit_rounds".to_string(), Json::Num(full as f64));
            obj.insert("audit_rounds".to_string(), Json::Num(audits as f64));
            obj.insert("avg_efficiency".to_string(), Json::Num(eff));
            rows.push(Json::Obj(obj));
        }
        let (bern, lat) = (full_by_policy[0], full_by_policy[1]);
        println!(
            "n={n}: latency-selective used {lat} full-audit rounds vs bernoulli's \
             {bern} to identify the slow Byzantine worker{}",
            if lat < bern { "" } else { "  ** EXPECTED STRICTLY FEWER **" }
        );
    }
    table.print("latency-audit sweep (counts up to and including identification)");
    println!(
        "\nnote: at equal q budget the latency-selective policy concentrates its \
         per-worker audits on the straggler (latency anomaly saturates after ~7 \
         rounds, suspicion ~0.5), so it identifies the liar without ever paying a \
         full n-chunk audit; Bernoulli(q) must land a full audit on a tampering \
         round."
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("latency_audit".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(
            "linreg d=4 chunk=2 transport=sim latency=fixed:100us gather=all \
             byzantine=[n-1] attack=sign_flip p=0.3 stragglers=[(n-1,50x)] \
             policies=bernoulli:0.2|latency-selective:0.2 steps=400"
                .to_string(),
        ),
    );
    doc.insert("results".to_string(), Json::Arr(rows));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_latency_audit.json", &json) {
        Ok(()) => println!("\nwrote BENCH_latency_audit.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_latency_audit.json: {e}"),
    }
}
