//! L3 hot-path benchmarks: coordinator overhead excluding gradient
//! compute (PERF row in DESIGN.md) plus end-to-end iterations/s on the
//! native engine across cluster sizes and schemes.

use r3bft::config::{AttackKind, PolicyKind};
use r3bft::coordinator::assignment::Assignment;
use r3bft::coordinator::codes::{check_copies, grad_key, SymbolCopy};
use r3bft::coordinator::identify::majority_vote;
use r3bft::experiments::common::RunSpec;
use r3bft::util::bench::{black_box, run, BenchOpts, Table};
use r3bft::util::rng::Pcg64;

fn main() {
    let opts = BenchOpts::default();
    let mut rng = Pcg64::seeded(7);

    println!("#### coordinator primitives (d = 4096)");
    let d = 4096usize;
    let grad = rng.gauss_vec(d);
    run("grad_key (FNV over 4096 f32)", opts, || {
        black_box(grad_key(black_box(&grad), 1.0));
    });

    let copies: Vec<SymbolCopy> = (0..3)
        .map(|w| SymbolCopy { worker: w, grad: grad.clone(), loss: 1.0, wire: None })
        .collect();
    run("check_copies r=3 unanimous", opts, || {
        black_box(check_copies(black_box(&copies), 0.0));
    });

    let mut vote_copies = copies.clone();
    vote_copies.push(SymbolCopy { worker: 3, grad: rng.gauss_vec(d), loss: 2.0, wire: None });
    vote_copies.push(SymbolCopy { worker: 4, grad: grad.clone(), loss: 1.0, wire: None });
    run("majority_vote 5 copies f=2", opts, || {
        black_box(majority_vote(black_box(&vote_copies), 2));
    });

    let active: Vec<usize> = (0..32).collect();
    let ids: Vec<usize> = (0..32 * 8).collect();
    run("assignment n=32 r=3", opts, || {
        black_box(Assignment::new(black_box(&ids), black_box(&active), 3));
    });

    let aggregate_inputs: Vec<Vec<f32>> = (0..32).map(|_| rng.gauss_vec(d)).collect();
    let mut acc = vec![0.0f32; d];
    run("aggregate 32 chunks d=4096 (axpy)", opts, || {
        acc.iter_mut().for_each(|v| *v = 0.0);
        for g in &aggregate_inputs {
            r3bft::linalg::axpy(1.0 / 32.0, black_box(g), &mut acc);
        }
        black_box(&acc);
    });

    println!("\n#### end-to-end iterations/s (native linreg d=16, chunk=8)");
    let mut table = Table::new(&["n", "f", "scheme", "iters/s", "us/iter"]);
    for &(n, f) in &[(5usize, 1usize), (9, 2), (17, 4), (33, 8)] {
        for (name, policy) in [
            ("vanilla", PolicyKind::None),
            ("randomized q=.2", PolicyKind::Bernoulli { q: 0.2 }),
            ("deterministic", PolicyKind::Deterministic),
        ] {
            let steps = 300usize;
            let t0 = std::time::Instant::now();
            let (out, _) = RunSpec::new(n, f, policy)
                .attack(AttackKind::SignFlip, 0.2, 2.0)
                .steps(steps)
                .seed(1)
                .run_linreg()
                .unwrap();
            let dt = t0.elapsed().as_secs_f64();
            black_box(out);
            table.row(&[
                n.to_string(),
                f.to_string(),
                name.into(),
                format!("{:.0}", steps as f64 / dt),
                format!("{:.0}", dt / steps as f64 * 1e6),
            ]);
        }
    }
    table.print("L3 end-to-end throughput");
}
