//! PJRT runtime benchmarks: artifact compile latency and steady-state
//! execution latency/throughput for every artifact kind. These are the
//! L2/L1 numbers the perf pass tracks (EXPERIMENTS.md §Perf).
//!
//! Skipped gracefully when `artifacts/` is missing.

use std::sync::Arc;

use r3bft::data::{Corpus, Dataset, LinRegDataset};
use r3bft::grad::{models, GradientComputer, ModelSpec, XlaEngine};
use r3bft::runtime::Runtime;
use r3bft::util::bench::{black_box, run, slow_opts, BenchOpts};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("bench_runtime: artifacts/ not built (run `make artifacts`) — skipping");
        return;
    }
    let rt = Arc::new(Runtime::cpu("artifacts").expect("runtime"));

    // compile latency for each artifact (one-time cost per process)
    println!("#### artifact compile latency");
    for name in ["linreg_grad_d64_b256", "mlp_grad_i32_h64_c4_b128", "tfm_grad_tiny", "sgd_tfm_tiny"] {
        let t0 = std::time::Instant::now();
        rt.preload(name).expect("preload");
        println!("compile {:<26} {:8.1} ms", name, t0.elapsed().as_secs_f64() * 1e3);
    }

    // steady-state execution latency
    println!("\n#### steady-state execution (per call, includes host<->literal copies)");
    let opts = BenchOpts::default();

    let spec = ModelSpec::LinReg { d: 64, batch: 256 };
    let eng = XlaEngine::new(rt.clone(), spec.clone()).expect("engine");
    let ds = LinRegDataset::generate(256, 64, 0.0, 1);
    let batch = ds.batch(&(0..256).collect::<Vec<_>>());
    let theta = spec.init_theta(1);
    run("linreg_grad d=64 b=256 (16k pts/s unit)", opts, || {
        black_box(eng.grad(black_box(&theta), black_box(&batch)).unwrap());
    });

    let mut th = theta.clone();
    let g = vec![0.01f32; 64];
    run("sgd_update d=64", opts, || {
        eng.sgd_step(&mut th, black_box(&g), 0.1).unwrap();
    });

    let spec = ModelSpec::Mlp { in_dim: 32, hidden: 64, classes: 4, batch: 128 };
    let eng = XlaEngine::new(rt.clone(), spec.clone()).expect("engine");
    let ds = r3bft::data::BlobsDataset::generate(128, 32, 4, 4.0, 2);
    let batch = ds.batch(&(0..128).collect::<Vec<_>>());
    let theta = spec.init_theta(2);
    run("mlp_grad i=32 h=64 c=4 b=128", opts, || {
        black_box(eng.grad(black_box(&theta), black_box(&batch)).unwrap());
    });

    let spec = ModelSpec::Transformer { param_dim: 136_512, batch: 8, seq_len: 65 };
    let eng = XlaEngine::new(rt.clone(), spec).expect("engine");
    let corpus = Corpus::synthetic(8192, 65, 3);
    let batch = corpus.batch(&(0..8).map(|i| i * 13).collect::<Vec<_>>());
    let theta = models::init_transformer_tiny(3);
    run("tfm_grad 136k params b=8 T=64", slow_opts(), || {
        black_box(eng.grad(black_box(&theta), black_box(&batch)).unwrap());
    });
    let mut th = theta.clone();
    let g = vec![1e-4f32; 136_512];
    run("sgd_update 136k params", opts, || {
        eng.sgd_step(&mut th, black_box(&g), 0.1).unwrap();
    });

    let s = rt.stats();
    println!(
        "\ntotal: {} executions, mean {:.2} ms; {} compilations, {:.0} ms",
        s.executions,
        s.mean_exec_us() / 1e3,
        s.compilations,
        s.total_compile_ns as f64 / 1e6
    );
}
