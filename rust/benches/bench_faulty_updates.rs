//! Bench E3: probability of faulty updates vs Eq. (3).

fn main() {
    let fast = !std::env::args().any(|a| a == "--full");
    r3bft::experiments::run("e3", fast).unwrap();
}
