//! Bench E2/E6/E8: computation-efficiency tables (Eq. 2, scheme
//! comparison, deterministic staircase). `--full` for paper-scale runs.

fn main() {
    let fast = !std::env::args().any(|a| a == "--full");
    r3bft::experiments::run("e2", fast).unwrap();
    r3bft::experiments::run("e6", fast).unwrap();
    r3bft::experiments::run("e8", fast).unwrap();
}
