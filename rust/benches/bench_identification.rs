//! Bench E4/E9: identification bound + §5 generalizations.

fn main() {
    let fast = !std::env::args().any(|a| a == "--full");
    r3bft::experiments::run("e4", fast).unwrap();
    r3bft::experiments::run("e9", fast).unwrap();
}
