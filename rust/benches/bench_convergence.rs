//! Bench E7: exact fault-tolerance grid (scheme x attack) — Def. 1.

fn main() {
    let fast = !std::env::args().any(|a| a == "--full");
    r3bft::experiments::run("e7", fast).unwrap();
}
