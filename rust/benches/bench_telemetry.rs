//! Telemetry overhead bench, written to `BENCH_telemetry.json`: the
//! same fault-free loopback-TCP linreg workload with worker telemetry
//! off (no recorder: the PR 8/9 wire) and on (recorder attached:
//! worker spans, clock sync, Telemetry frames). Reported per n: mean
//! wall round time for each mode and the on/off ratio. The acceptance
//! gate asserts the overhead at n=32 stays under 5% of the round time
//! — telemetry is control plane and must never become a tax on the
//! protocol. Each mode takes the best of `TRIALS` runs so scheduler
//! noise can only inflate the ratio, not hide a real regression.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;

use r3bft::config::{AttackConfig, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::transport::net::server;
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};
use r3bft::trace::Recorder;
use r3bft::util::bench::{black_box, Table};
use r3bft::util::json::Json;

/// Best-of trials per (n, mode): loopback TCP timing is at the mercy
/// of the scheduler; the minimum is the honest cost floor.
const TRIALS: usize = 3;

fn spawn_worker_threads(n: usize) -> (Vec<String>, Vec<JoinHandle<()>>) {
    let mut peers = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        peers.push(listener.local_addr().expect("local addr").to_string());
        handles.push(std::thread::spawn(move || {
            server::serve(listener).expect("worker serve");
        }));
    }
    (peers, handles)
}

/// One fault-free loopback net run; returns mean wall seconds per
/// round. `telemetry` attaches a recorder, which switches the worker
/// spans + clock sync + Telemetry frames on end to end.
fn run_once(n: usize, steps: usize, telemetry: bool) -> f64 {
    let d = 16usize;
    let chunk = 8usize;
    let mut cluster = ClusterConfig::new(n, 1, 42);
    cluster.byzantine_ids = vec![];
    cluster.f = 0;
    cluster.transport = "net".into();
    let (peers, workers) = spawn_worker_threads(n);
    cluster.peers = peers;
    let cfg = ExperimentConfig {
        name: format!("bench-telemetry-{n}-{telemetry}"),
        cluster,
        policy: PolicyKind::None,
        attack: AttackConfig::default(),
        adversary: None,
        train: TrainConfig { steps, lr: 0.1, ..Default::default() },
    };
    let ds = Arc::new(LinRegDataset::generate(4096, d, 0.0, 42));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(42);
    let opts = MasterOptions {
        net_model: Some(spec.clone()),
        recorder: telemetry.then(Recorder::new),
        ..Default::default()
    };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    let t0 = std::time::Instant::now();
    let out = master.run().expect("run");
    let dt = t0.elapsed().as_secs_f64();
    black_box(out);
    for h in workers {
        h.join().expect("worker thread");
    }
    dt / steps as f64
}

fn best_of(n: usize, steps: usize, telemetry: bool) -> f64 {
    (0..TRIALS)
        .map(|_| run_once(n, steps, telemetry))
        .fold(f64::INFINITY, f64::min)
}

fn main() {
    println!("#### worker telemetry overhead on the loopback net transport (linreg d=16, chunk=8)");
    let steps = 40usize;
    let mut table = Table::new(&["n", "off us/round", "on us/round", "on/off", "overhead %"]);
    let mut rows: Vec<Json> = Vec::new();
    let mut gate_overhead = None;
    for &n in &[8usize, 32] {
        let off_s = best_of(n, steps, false);
        let on_s = best_of(n, steps, true);
        let ratio = on_s / off_s.max(1e-12);
        let overhead_pct = (ratio - 1.0) * 100.0;
        if n == 32 {
            gate_overhead = Some(overhead_pct);
        }
        table.row(&[
            n.to_string(),
            format!("{:.1}", off_s * 1e6),
            format!("{:.1}", on_s * 1e6),
            format!("{ratio:.3}x"),
            format!("{overhead_pct:.2}"),
        ]);
        let mut obj = BTreeMap::new();
        obj.insert("n".to_string(), Json::Num(n as f64));
        obj.insert("off_us_per_round".to_string(), Json::Num(off_s * 1e6));
        obj.insert("on_us_per_round".to_string(), Json::Num(on_s * 1e6));
        obj.insert("on_over_off".to_string(), Json::Num(ratio));
        obj.insert("overhead_pct".to_string(), Json::Num(overhead_pct));
        rows.push(Json::Obj(obj));
    }
    table.print("telemetry sweep (wall time per round, best of 3 runs per mode)");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("net_telemetry_overhead".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(
            "linreg d=16 chunk=8 policy=none fault-free steps=40 \
             net=loopback-tcp-worker-threads, telemetry off (no recorder) vs on \
             (recorder attached), best of 3"
                .to_string(),
        ),
    );
    doc.insert("gate".to_string(), Json::Str("overhead_pct < 5 at n=32".to_string()));
    doc.insert("results".to_string(), Json::Arr(rows));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_telemetry.json", &json) {
        Ok(()) => println!("\nwrote BENCH_telemetry.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_telemetry.json: {e}"),
    }

    // acceptance gate: the control plane must stay under 5% of the
    // round time at the big end of the sweep
    let overhead = gate_overhead.expect("n=32 row");
    assert!(
        overhead < 5.0,
        "telemetry overhead {overhead:.2}% at n=32 breaches the 5% budget"
    );
    println!("telemetry overhead gate passed: {overhead:.2}% < 5% at n=32");
}
