//! Net-vs-threaded wall-clock sweep, written to `BENCH_net.json`: the
//! same fault-free linreg workload over (a) loopback TCP worker
//! threads hosting the standalone worker core and (b) the in-process
//! threaded pool, at n ∈ {8, 32}. Reported per n: mean wall round
//! time for each transport, the net/threaded ratio (the price of
//! frames + sockets at loopback), and the honest wire bytes per round
//! the net transport measures (frame overhead and theta broadcast
//! included) next to the payload-only figure the threaded transport
//! estimates.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;

use r3bft::config::{AttackConfig, ClusterConfig, ExperimentConfig, PolicyKind, TrainConfig};
use r3bft::coordinator::master::{Master, MasterOptions};
use r3bft::coordinator::transport::net::server;
use r3bft::data::LinRegDataset;
use r3bft::grad::{GradientComputer, ModelSpec, NativeEngine};
use r3bft::util::bench::{black_box, Table};
use r3bft::util::json::Json;

fn spawn_worker_threads(n: usize) -> (Vec<String>, Vec<JoinHandle<()>>) {
    let mut peers = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        peers.push(listener.local_addr().expect("local addr").to_string());
        handles.push(std::thread::spawn(move || {
            server::serve(listener).expect("worker serve");
        }));
    }
    (peers, handles)
}

/// One fault-free run; returns (mean wall round seconds, mean
/// bytes_round).
fn run_once(n: usize, transport: &str, peers: Vec<String>, steps: usize) -> (f64, f64) {
    let d = 16usize;
    let chunk = 8usize;
    let mut cluster = ClusterConfig::new(n, 1, 42);
    cluster.byzantine_ids = vec![];
    cluster.f = 0;
    cluster.transport = transport.into();
    cluster.peers = peers;
    let cfg = ExperimentConfig {
        name: format!("bench-net-{transport}-{n}"),
        cluster,
        policy: PolicyKind::None,
        attack: AttackConfig::default(),
        adversary: None,
        train: TrainConfig { steps, lr: 0.1, ..Default::default() },
    };
    let ds = Arc::new(LinRegDataset::generate(4096, d, 0.0, 42));
    let spec = ModelSpec::LinReg { d, batch: chunk };
    let engine: Arc<dyn GradientComputer> = Arc::new(NativeEngine::new(spec.clone()));
    let theta0 = spec.init_theta(42);
    let opts = MasterOptions { net_model: Some(spec.clone()), ..Default::default() };
    let master = Master::new(cfg, opts, engine, ds, theta0, chunk).expect("master");
    let t0 = std::time::Instant::now();
    let out = master.run().expect("run");
    let dt = t0.elapsed().as_secs_f64();
    let bytes: u64 = out.metrics.iterations.iter().map(|r| r.bytes_round).sum();
    let mean_bytes = bytes as f64 / steps as f64;
    black_box(out);
    (dt / steps as f64, mean_bytes)
}

fn main() {
    println!("#### net (loopback TCP) vs threaded, wall round time (linreg d=16, chunk=8)");
    let steps = 40usize;
    let mut table = Table::new(&[
        "n",
        "threaded us/round",
        "net us/round",
        "net/threaded",
        "threaded B/round",
        "net B/round",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for &n in &[8usize, 32] {
        let (thr_s, thr_bytes) = run_once(n, "threaded", vec![], steps);
        let (peers, workers) = spawn_worker_threads(n);
        let (net_s, net_bytes) = run_once(n, "net", peers, steps);
        for h in workers {
            h.join().expect("worker thread");
        }
        let ratio = net_s / thr_s.max(1e-12);
        table.row(&[
            n.to_string(),
            format!("{:.1}", thr_s * 1e6),
            format!("{:.1}", net_s * 1e6),
            format!("{ratio:.2}x"),
            format!("{thr_bytes:.0}"),
            format!("{net_bytes:.0}"),
        ]);
        let mut obj = BTreeMap::new();
        obj.insert("n".to_string(), Json::Num(n as f64));
        obj.insert("threaded_us_per_round".to_string(), Json::Num(thr_s * 1e6));
        obj.insert("net_us_per_round".to_string(), Json::Num(net_s * 1e6));
        obj.insert("net_over_threaded".to_string(), Json::Num(ratio));
        obj.insert("threaded_bytes_per_round".to_string(), Json::Num(thr_bytes));
        obj.insert("net_bytes_per_round".to_string(), Json::Num(net_bytes));
        rows.push(Json::Obj(obj));
    }
    table.print("net sweep (wall time per round; bytes are the honest wire figure for net)");
    println!(
        "\nnote: the net byte column includes frame headers and the per-request \
         theta broadcast — overhead the in-process transports never pay or \
         measure — so it dominates the threaded payload-only estimate."
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("net_transport".to_string()));
    doc.insert(
        "workload".to_string(),
        Json::Str(
            "linreg d=16 chunk=8 policy=none fault-free steps=40 \
             net=loopback-tcp-worker-threads vs threaded"
                .to_string(),
        ),
    );
    doc.insert("results".to_string(), Json::Arr(rows));
    let json = Json::Obj(doc).to_string();
    match std::fs::write("BENCH_net.json", &json) {
        Ok(()) => println!("\nwrote BENCH_net.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_net.json: {e}"),
    }
}
