//! Bench E5: adaptive q*_t — closed form vs numeric argmin, boundary
//! conditions, and the trajectory during an attacked run.

fn main() {
    let fast = !std::env::args().any(|a| a == "--full");
    r3bft::experiments::run("e5", fast).unwrap();
}
